"""Unit + property tests for the ReCross core (the paper's algorithms)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CoOccurrenceGraph,
    build_cooccurrence,
    correlation_aware_grouping,
    frequency_grouping,
    naive_grouping,
    activations_per_query,
    log_scaled_copies,
    plan_replication,
    build_layout,
    query_tile_bitmaps,
    select_mode,
    popcount,
    energy_breakeven_rows,
    mode_statistics,
    simulate_batch,
    simulate_nmars_baseline,
    merge_graphs,
    baselines,
    READ_MODE, MAC_MODE,
)
from repro.core.energy import DEFAULT_RERAM
from repro.data import zipf_queries


# ------------------------------------------------------------ fixtures --

def small_trace(rows=512, n=128, seed=0, bag=12.0):
    return zipf_queries(rows, n, bag, seed=seed)


# --------------------------------------------------------- cooccurrence --

def test_cooccurrence_counts_and_symmetry():
    queries = [[0, 1, 2], [1, 2], [2, 3], [0, 2]]
    g = build_cooccurrence(queries, 4)
    assert g.num_queries == 4
    assert g.freq.tolist() == [2, 2, 4, 1]
    assert g.weight(1, 2) == 2 and g.weight(2, 1) == 2
    assert g.weight(0, 3) == 0
    assert g.edge_count() == 4  # (0,1),(0,2),(1,2),(2,3)

def test_cooccurrence_dedups_within_query():
    g = build_cooccurrence([[5, 5, 5]], 8)
    assert g.freq[5] == 1
    assert g.degree(5) == 0


def test_merge_graphs_adds():
    a = build_cooccurrence([[0, 1]], 4)
    b = build_cooccurrence([[0, 1], [1, 2]], 4)
    m = merge_graphs(a, b)
    assert m.weight(0, 1) == 2
    assert m.freq.tolist() == [2, 3, 1, 0]


# ------------------------------------------------------------- grouping --

@given(st.integers(1, 8), st.integers(20, 200), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_grouping_is_partition(group_pow, rows, seed):
    """Property: every row grouped exactly once, group sizes <= group_size."""
    group_size = 2 ** group_pow
    qs = small_trace(rows=rows, n=40, seed=seed, bag=6.0)
    g = build_cooccurrence(qs, rows)
    grouping = correlation_aware_grouping(g, group_size)
    seen = sorted(r for grp in grouping.groups for r in grp)
    assert seen == list(range(rows))
    assert all(len(grp) <= group_size for grp in grouping.groups)
    # only the last group may be short
    assert all(len(grp) == group_size for grp in grouping.groups[:-1])
    # index maps consistent
    for gi, grp in enumerate(grouping.groups):
        for slot, r in enumerate(grp):
            assert grouping.group_of[r] == gi and grouping.slot_of[r] == slot


def test_grouping_reduces_activations_vs_naive():
    rows = 1024
    qs = small_trace(rows=rows, n=256, seed=1)
    g = build_cooccurrence(qs[:128], rows)
    rx = correlation_aware_grouping(g, 64)
    nv = naive_grouping(rows, 64)
    fr = frequency_grouping(g, 64)
    a_rx = activations_per_query(rx, qs[128:]).sum()
    a_nv = activations_per_query(nv, qs[128:]).sum()
    a_fr = activations_per_query(fr, qs[128:]).sum()
    assert a_rx < a_nv, "correlation grouping must beat naive"
    # frequency grouping can come within noise at small synthetic scale;
    # correlation grouping must never be meaningfully worse
    assert a_rx <= a_fr * 1.05, "correlation grouping must not lose to frequency"


# ---------------------------------------------------------- replication --

def test_log_scaled_copies_matches_eq1():
    """Eq. 1: floor(log(freq)/log(freq_total) * log(batch)) extra copies."""
    import math
    freq = np.array([1000, 100, 10, 1, 0])
    batch = 256
    out = log_scaled_copies(freq, batch)
    total = freq.sum()
    for f, c in zip(freq, out):
        if f < 1:
            assert c == 1
        else:
            expect = 1 + max(
                int(math.floor(math.log(f) / math.log(total) * math.log(batch))), 0
            )
            assert c == expect, (f, c, expect)


@given(st.integers(2, 512))
@settings(max_examples=20, deadline=None)
def test_log_scaling_bounds(batch):
    """Property: copies >= 1; hottest group gets the most copies; total
    extra copies bounded by log(batch) per group."""
    import math
    freq = np.array([10_000, 500, 20, 3, 1, 0])
    out = log_scaled_copies(freq, batch)
    assert (out >= 1).all()
    assert out[0] == out.max()
    assert (out - 1 <= math.log(batch) + 1).all()


def test_area_budget_caps_extra_copies():
    qs = small_trace(rows=512, n=256, seed=2)
    g = build_cooccurrence(qs, 512)
    grouping = correlation_aware_grouping(g, 32)
    for budget in (0.0, 0.05, 0.2):
        plan = plan_replication(grouping, g.freq, 256, area_budget_ratio=budget)
        assert plan.extra_tiles() <= int(budget * grouping.num_groups)


# ------------------------------------------------------ layout / bitmaps --

def test_layout_physical_row_and_image():
    rows, dim = 64, 8
    qs = [[i, (i + 1) % rows] for i in range(rows)]
    g = build_cooccurrence(qs, rows)
    grouping = correlation_aware_grouping(g, 16)
    plan = plan_replication(grouping, g.freq, 8)
    layout = build_layout(grouping, plan, dim)
    table = np.arange(rows * dim, dtype=np.float32).reshape(rows, dim)
    image = layout.build_image(table)
    assert image.shape == (layout.num_tiles * 16, dim)
    for r in range(rows):
        for rep in range(int(layout.copies[layout.group_of[r]])):
            assert (image[layout.physical_row(r, rep)] == table[r]).all()


def test_query_bitmaps_round_robin_balances_replicas():
    rows = 64
    g = build_cooccurrence([[0]] * 10, rows)
    grouping = naive_grouping(rows, 16)
    plan = plan_replication(grouping, g.freq * 0 + 100, 64)  # force copies
    layout = build_layout(grouping, plan, 8)
    if layout.copies[0] > 1:
        bitmaps, counts = query_tile_bitmaps(layout, [[0]] * 6)
        used_tiles = set(np.nonzero(counts.sum(axis=0))[0].tolist())
        assert len(used_tiles) > 1, "round robin should spread replicas"


# ------------------------------------------------------- dynamic switch --

def test_select_mode_and_popcount():
    bm = np.zeros((4, 8), np.uint8)
    bm[1, 3] = 1
    bm[2, [1, 2]] = 1
    counts = popcount(bm)
    assert counts.tolist() == [0, 1, 2, 0]
    modes = select_mode(counts)
    assert modes[1] == READ_MODE and modes[2] == MAC_MODE

def test_energy_breakeven_row_count():
    """READ strictly beats MAC for single rows (the paper's rule is sound);
    the model's actual breakeven is ~9 rows (flash-ADC dominance) — the
    headroom exploited by the beyond-paper multi-read policy."""
    be = energy_breakeven_rows(DEFAULT_RERAM)
    assert be > 1, "single-row READ must be cheaper than MAC"
    assert 4 <= be <= 16, f"breakeven {be} outside plausible ADC-dominated range"


def test_mode_statistics_fractions():
    counts = np.array([[0, 1, 1, 5], [2, 0, 1, 0]])
    s = mode_statistics(counts)
    assert s["activations"] == 5
    assert abs(s["read_fraction"] - 3 / 5) < 1e-9


# ------------------------------------------------------------ simulator --

def test_simulator_energy_single_vs_mac():
    """Dynamic switching must strictly reduce energy when single-row
    activations exist, and never change the math."""
    rows = 256
    qs = small_trace(rows=rows, n=64, seed=3, bag=3.0)
    g = build_cooccurrence(qs[:32], rows)
    layout, _ = baselines.recross_pipeline(g, qs[32:], group_size=16, dim=8)
    on = simulate_batch(layout, qs[32:], dynamic_switching=True)
    off = simulate_batch(layout, qs[32:], dynamic_switching=False)
    assert on.activations == off.activations
    if on.read_activations > 0:
        assert on.energy_pj < off.energy_pj

def test_simulator_replication_reduces_completion_time():
    rows = 256
    qs = small_trace(rows=rows, n=256, seed=4, bag=4.0)
    g = build_cooccurrence(qs[:128], rows)
    _, with_rep = baselines.recross_pipeline(
        g, qs[128:], group_size=16, dim=8, batch_size=128, replication_scheme="log"
    )
    _, without = baselines.recross_pipeline(
        g, qs[128:], group_size=16, dim=8, batch_size=128, replication_scheme="none"
    )
    assert with_rep.completion_time_ns <= without.completion_time_ns

def test_nmars_slower_than_recross():
    rows = 512
    qs = small_trace(rows=rows, n=256, seed=5)
    g = build_cooccurrence(qs[:128], rows)
    _, rx = baselines.recross_pipeline(g, qs[128:], batch_size=128)
    _, nm = baselines.nmars_pipeline(rows, qs[128:])
    assert rx.completion_time_ns < nm.completion_time_ns
    assert rx.energy_pj < nm.energy_pj


@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_simulation_deterministic(seed):
    rows = 128
    qs = small_trace(rows=rows, n=32, seed=seed, bag=4.0)
    g = build_cooccurrence(qs, rows)
    l1, r1 = baselines.recross_pipeline(g, qs, group_size=16, dim=8)
    l2, r2 = baselines.recross_pipeline(g, qs, group_size=16, dim=8)
    assert r1.completion_time_ns == r2.completion_time_ns
    assert r1.energy_pj == r2.energy_pj
    assert (l1.gather_index_map() == l2.gather_index_map()).all()
