"""Numerical equivalence tests for every optimized model path against its
simple reference implementation."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import attention as attn
from repro.models import mamba2, xlstm
from repro.models.moe import apply_moe, init_moe
from repro.models.rope import apply_rope


def test_mlstm_chunked_equals_sequential():
    p = xlstm.init_mlstm(jax.random.PRNGKey(0), 64, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 70, 64)) * 0.5
    y_seq, (C1, n1, m1) = xlstm.mlstm_scan(p, x, 4)
    y_chk, (C2, n2, m2) = xlstm.mlstm_chunked(p, x, 4, chunk=16)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chk), atol=3e-5)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)


def test_mlstm_chunked_grad_finite_long_gates():
    """Extreme gate pre-activations must not produce NaN gradients."""
    p = xlstm.init_mlstm(jax.random.PRNGKey(0), 32, 2, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32)) * 4.0  # big inputs
    g = jax.grad(lambda xx: xlstm.mlstm_chunked(p, xx, 2, chunk=16)[0].sum())(x)
    assert bool(jnp.isfinite(g).all())


def test_mamba2_chunked_equals_stepwise():
    p = mamba2.init_mamba2(jax.random.PRNGKey(0), 48, 16, jnp.float32, head_dim=32)
    b, s = 2, 33
    u = jax.random.normal(jax.random.PRNGKey(1), (b, s, 48)) * 0.5
    y_chunk, (h_last, _) = mamba2.mamba2_scan(p, u, ssm_state=16, head_dim=32, chunk=8)
    state = jnp.zeros((b, 3, 32, 16), jnp.float32)
    conv = jnp.zeros((b, mamba2.CONV_W - 1, 96), jnp.float32)
    ys = []
    for t in range(s):
        y, state, conv = mamba2.mamba2_decode_step(
            p, u[:, t:t + 1], state, conv, ssm_state=16, head_dim=32
        )
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(y_chunk), np.asarray(jnp.concatenate(ys, 1)), atol=2e-5
    )
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(state), atol=1e-5)


def test_mamba2_grad_finite():
    p = mamba2.init_mamba2(jax.random.PRNGKey(0), 32, 8, jnp.float32, head_dim=16)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    g = jax.grad(lambda uu: mamba2.apply_mamba2(p, uu, ssm_state=8, head_dim=16, chunk=8).sum())(u)
    assert bool(jnp.isfinite(g).all())


def test_chunked_attention_equals_full():
    d, H, KV, hd = 64, 4, 2, 16
    p = attn.init_attention(jax.random.PRNGKey(0), d, H, KV, hd, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, d)) * 0.3
    full = attn.self_attention(p, x, num_heads=H, kv_heads=KV, head_dim=hd)
    chunked = attn.chunked_self_attention(
        p, x, num_heads=H, kv_heads=KV, head_dim=hd, q_chunk=16, k_chunk=16
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), atol=2e-5)


def test_chunked_attention_windowed_equals_full_windowed():
    d, H, KV, hd = 32, 2, 2, 16
    p = attn.init_attention(jax.random.PRNGKey(0), d, H, KV, hd, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, d)) * 0.3
    full = attn.self_attention(p, x, num_heads=H, kv_heads=KV, head_dim=hd, window=8)
    chunked = attn.chunked_self_attention(
        p, x, num_heads=H, kv_heads=KV, head_dim=hd, q_chunk=16, k_chunk=16, window=8
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), atol=2e-5)


def test_cross_attention_chunked_equals_direct():
    d, H, KV, hd = 32, 4, 2, 8
    p = attn.init_cross_attention(jax.random.PRNGKey(0), d, H, KV, hd, d, jnp.float32)
    # non-zero gate so the output is informative
    p = dict(p, gate=jnp.ones((1,)))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, d)) * 0.3
    enc = jax.random.normal(jax.random.PRNGKey(2), (2, 7, d)) * 0.3
    direct = attn.cross_attention(p, x, enc, num_heads=H, kv_heads=KV, head_dim=hd,
                                  q_chunk=1024)  # no chunking (s < q_chunk)
    chunked = attn.cross_attention(p, x, enc, num_heads=H, kv_heads=KV, head_dim=hd,
                                   q_chunk=16)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked), atol=2e-5)


def test_rope_partial_rotates_half():
    x = jnp.ones((1, 4, 2, 8))
    pos = jnp.arange(4)[None, :]
    full = apply_rope(x, pos, theta=100.0, partial=False)
    part = apply_rope(x, pos, theta=100.0, partial=True)
    # partial: second half of head dims untouched
    np.testing.assert_array_equal(np.asarray(part[..., 4:]), np.ones((1, 4, 2, 4)))
    assert not np.allclose(np.asarray(full[..., 4:]), np.ones((1, 4, 2, 4)))
    # position 0 is identity everywhere
    np.testing.assert_allclose(np.asarray(part[0, 0]), np.ones((2, 8)), atol=1e-6)


def test_moe_grouped_dispatch_equals_global_nodrop():
    d, f, E, k = 32, 64, 8, 2
    moe = MoEConfig(num_experts=E, top_k=k, capacity_factor=float(E))
    p = init_moe(jax.random.PRNGKey(0), d, f, moe, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d))
    y1, _ = apply_moe(p, x, moe, num_groups=1)
    y4, _ = apply_moe(p, x, moe, num_groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-6)


def test_moe_capacity_drops_bounded():
    """With cf=1.0, the per-token output must be either the full top-k
    combination or a partial one — never amplified."""
    d, f, E, k = 16, 32, 4, 2
    moe = MoEConfig(num_experts=E, top_k=k, capacity_factor=1.0)
    p = init_moe(jax.random.PRNGKey(0), d, f, moe, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, d))
    y, aux = apply_moe(p, x, moe)
    assert bool(jnp.isfinite(y).all()) and float(aux) > 0
    # upper bound: no output exceeds the no-drop magnitude by more than fp noise
    y_full, _ = apply_moe(p, x, MoEConfig(E, k, float(E)))
    assert float(jnp.abs(y).max()) <= float(jnp.abs(y_full).max()) * 1.5 + 1e-3


def test_moe_grad_finite():
    d, f, E, k = 16, 32, 4, 2
    moe = MoEConfig(num_experts=E, top_k=k)
    p = init_moe(jax.random.PRNGKey(0), d, f, moe, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    g = jax.grad(lambda pp: apply_moe(pp, x, moe)[0].sum())(p)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))


def test_int8_kv_cache_quantization_error_bounded():
    from repro.configs import get_config
    from repro.models import forward, init_lm
    from repro.serve.decode import decode_step
    from repro.serve.kvcache import init_cache

    cfg = get_config("stablelm-3b", smoke=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, tokens)
    cache = init_cache(cfg, 2, 16, quant=True)
    outs = []
    for t in range(8):
        lg, cache = decode_step(params, cfg, tokens[:, t:t + 1], cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    # int8 cache: logits deviation stays small relative to logit scale
    scale = float(jnp.abs(full).max())
    assert float(jnp.abs(full - dec).max()) < 0.05 * max(scale, 1.0)
