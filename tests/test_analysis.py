"""Correctness tooling (DESIGN.md §12): the invariant checker must
reject each deliberately corrupted plan/patch/server fixture with a
precise message while passing every REAL plan and patch the replan and
paging pipelines produce (no false positives); the lock-discipline
analyzer must bless the current tree, detect crafted lock-order and
unguarded-shared-write bugs, and its runtime monitor must observe only
blessed-order acquisitions under real multi-producer stress; the repo
lint must run clean on the tree and catch each rule's crafted
violation.
"""

import dataclasses
import threading

import numpy as np
import pytest

from repro.analysis import (
    InvariantViolation,
    LockMonitor,
    LockOrderError,
    analyze_locks,
    monitor_server,
    run_lint,
    validate_patch,
    validate_plan,
    validate_server_state,
)
from repro.analysis.races import BLESSED_LOCK_ORDER, OrderGraph
from repro.core import (
    build_cooccurrence,
    build_layout,
    correlation_aware_grouping,
    plan_replication,
)
from repro.data import zipf_queries
from repro.dist import (
    PagingPolicy,
    apply_plan_patch,
    compute_plan_patch,
    plan_shards,
)
from repro.dist.replan import PlanPatch
from repro.serve import ShardedEmbeddingServer

EQ1_BATCH = 64
ROWS, DIM = 192, 128


def _int_table(rows, dim, seed):
    """Integer-valued f32 table: partial sums are exact in float32."""
    return np.random.default_rng(seed).integers(
        -8, 9, size=(rows, dim)
    ).astype(np.float32)


def _plan(seed=3, S=2, capacity_frac=None):
    hist = zipf_queries(ROWS, 48, 6.0, seed=seed)
    g = build_cooccurrence(hist, ROWS)
    grouping = correlation_aware_grouping(g, 16)
    rplan = plan_replication(grouping, g.freq, EQ1_BATCH)
    layout = build_layout(grouping, rplan, DIM)
    gfreq = grouping.group_freq(g.freq)
    if capacity_frac is None:
        return plan_shards([layout], [rplan], S, group_freqs=[gfreq])
    uncapped = plan_shards([layout], [rplan], S, group_freqs=[gfreq])
    cap = max(2, int(uncapped.max_local_tiles * capacity_frac))
    return plan_shards([layout], [rplan], S, group_freqs=[gfreq],
                       capacity_tiles=cap)


def _server(**kw):
    tables = {"a": _int_table(ROWS, DIM, 11), "b": _int_table(ROWS, DIM, 12)}
    histories = {"a": zipf_queries(ROWS, 48, 5.0, seed=13),
                 "b": zipf_queries(ROWS, 48, 5.0, seed=14)}
    kw.setdefault("flush_policy", "per-shard")
    return ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=8, **kw,
    )


# ------------------------------------------------ invariants: rejects --


def test_fresh_plans_validate_clean():
    for S in (1, 2, 4):
        validate_plan(_plan(seed=S, S=S))
    validate_plan(_plan(seed=7, S=2, capacity_frac=0.5))


def test_duplicate_slot_rejected():
    sp = _plan()
    lto = sp.local_tile_of.copy()
    held = np.nonzero(lto[0] >= 0)[0]
    assert held.size >= 2
    lto[0, held[1]] = lto[0, held[0]]  # two tiles share one local slot
    bad = dataclasses.replace(sp, local_tile_of=lto)
    with pytest.raises(InvariantViolation, match="slot uniqueness violated"):
        validate_plan(bad)


def test_mutated_group_copies_rejected():
    sp = _plan()
    copies = sp.group_copies.copy()
    copies[0] += 1  # the fused tile space is frozen at plan build
    bad = dataclasses.replace(sp, group_copies=copies)
    with pytest.raises(InvariantViolation,
                       match="frozen tile space was mutated"):
        validate_plan(bad)


def test_resident_but_evicted_group_rejected():
    sp = _plan(capacity_frac=0.5)
    g = int(np.nonzero(sp.replicated_group)[0][0])
    patch = PlanPatch(
        promoted=[], demoted=[], dma=[], freed=[],
        new_capacity=int(sp.capacity_tiles),
        drifted_load=sp.group_load.copy(),
        evicted=[g], evicted_tiles=int(sp.group_copies[g]),
    )
    with pytest.raises(InvariantViolation,
                       match="not sharded-once resident"):
        validate_patch(sp, patch)


def test_evict_fetch_overlap_rejected():
    sp = _plan(capacity_frac=0.5)
    g = int(sp.cold_groups[0])
    patch = PlanPatch(
        promoted=[], demoted=[], dma=[], freed=[],
        new_capacity=int(sp.capacity_tiles),
        drifted_load=sp.group_load.copy(),
        fetched=[(g, 0)], evicted=[g],
    )
    with pytest.raises(InvariantViolation,
                       match="evict/fetch disjointness"):
        validate_patch(sp, patch)


def test_wrong_dma_count_and_slot_collision_rejected():
    sp = _plan()
    dload = sp.group_load[::-1].copy()
    patch = compute_plan_patch(sp, dload, eq1_batch=EQ1_BATCH)
    if not patch.promoted:
        pytest.skip("reversed load promoted nothing at this seed")
    # drop one promotion DMA: the Σ copies·(S-1) accounting must fire
    short = dataclasses.replace(patch, dma=patch.dma[:-1])
    with pytest.raises(InvariantViolation, match="promotion DMAs"):
        validate_patch(sp, short)
    # collide two DMAs into one (shard, slot): the simulation must fire
    if len(patch.dma) >= 2:
        s0, slot0, _t0 = patch.dma[0]
        _s1, _slot1, t1 = patch.dma[1]
        collided = dataclasses.replace(
            patch, dma=[patch.dma[0], (s0, slot0, t1)] + patch.dma[2:]
        )
        with pytest.raises(InvariantViolation, match="collides|already holds"):
            validate_patch(sp, collided)


def test_gseq_overflow_rejected():
    srv = _server(threaded=False)
    try:
        reg = srv._registry
        pid = reg.register("p0")
        # force the NEXT stamp past the packed int64 capacity
        reg._next[pid]["a"] = ((1 << 63) - 1) // reg.stride + 1
        with pytest.raises(InvariantViolation,
                           match="overflows the packed gseq capacity"):
            validate_server_state(srv)
    finally:
        srv.close()


# ------------------------------------- invariants: no false positives --


@pytest.mark.parametrize("seed,S", [(0, 1), (1, 2), (2, 4)])
def test_real_replan_patches_validate_clean(seed, S):
    sp = _plan(seed=seed, S=S)
    dload = sp.group_load[::-1].copy()
    patch = compute_plan_patch(sp, dload, eq1_batch=EQ1_BATCH)
    validate_patch(sp, patch)
    validate_plan(apply_plan_patch(sp, patch))


def test_real_paging_patches_validate_clean():
    sp = _plan(seed=5, S=2, capacity_frac=0.5)
    pol = PagingPolicy(capacity_tiles=int(sp.capacity_tiles), hysteresis=1.2)
    # rotate hotness onto the cold set so the patch pages both ways
    dload = sp.group_load[::-1].copy()
    patch = compute_plan_patch(sp, dload, eq1_batch=EQ1_BATCH, paging=pol)
    validate_patch(sp, patch)
    sp2 = apply_plan_patch(sp, patch)
    validate_plan(sp2)
    # and one more round on the patched (hole-y) plan
    patch2 = compute_plan_patch(sp2, sp.group_load.copy(),
                                eq1_batch=EQ1_BATCH, paging=pol)
    validate_patch(sp2, patch2)
    validate_plan(apply_plan_patch(sp2, patch2))


def test_live_server_state_validates_clean():
    srv = _server(threaded=True)
    try:
        validate_server_state(srv)
        rng = np.random.default_rng(0)
        for i in range(24):
            srv.submit("a" if i % 2 == 0 else "b",
                       rng.integers(0, ROWS, size=4), producer=f"p{i % 3}")
        srv.drain()  # quiesced validation runs inside via RECROSS_VALIDATE
        validate_server_state(srv, quiesced=True)
    finally:
        srv.close()


# ------------------------------------------------------ lock analyzer --


def test_static_lock_pass_blesses_current_tree():
    report = analyze_locks()
    assert report.findings() == []
    # the four coordinated locks are all discovered
    assert "ShardedEmbeddingServer" in report.locks
    assert {"_stamp_lock", "_engine_lock", "_results_lock"} <= (
        report.locks["ShardedEmbeddingServer"]
    )
    assert "_lock" in report.locks.get("ProducerRegistry", set())
    # every nesting edge among the blessed locks runs strictly forward
    idx = {n: i for i, n in enumerate(BLESSED_LOCK_ORDER)}
    for e in report.edges:
        if e.held == e.acquired:
            continue  # RLock reentrancy self-edge, allowed
        if e.held in idx and e.acquired in idx:
            assert idx[e.held] < idx[e.acquired], (e.held, e.acquired)


_CYCLE_SRC = '''
import threading

class ShardedEmbeddingServer:
    def __init__(self):
        self._engine_lock = threading.RLock()
        self._stamp_lock = threading.Lock()

    def forward(self):
        with self._engine_lock:
            with self._stamp_lock:
                pass

    def backward(self):
        with self._stamp_lock:
            with self._engine_lock:  # reversed: deadlocks vs forward()
                pass
'''


def test_crafted_lock_order_cycle_detected():
    report = analyze_locks(sources={"crafted.py": _CYCLE_SRC})
    findings = report.findings()
    assert any("runs backwards against the blessed order" in f
               for f in findings), findings
    assert report.cycles, "reversed nesting must form a cycle"


_UNGUARDED_SRC = '''
import threading

class Engine:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0

    def bump(self):
        with self._lock:
            self._count += 1

    def read(self):
        with self._lock:
            return self._count

    def racy_reset(self):
        self._count = 0
'''


def test_crafted_unguarded_write_detected():
    report = analyze_locks(sources={"crafted.py": _UNGUARDED_SRC})
    findings = report.findings()
    assert any("Engine._count" in f and "racy_reset" in f
               for f in findings), findings


def test_unlocked_marker_suppresses_documented_access():
    src = _UNGUARDED_SRC.replace(
        "        self._count = 0\n\n    def bump",
        "        self._count = 0\n\n    def bump",
    ).replace(
        "    def racy_reset(self):\n        self._count = 0",
        "    def racy_reset(self):\n"
        "        self._count = 0  # unlocked: single-threaded teardown",
    )
    report = analyze_locks(sources={"crafted.py": src})
    assert report.findings() == []


def test_lock_monitor_enforce_raises_on_backwards_acquisition():
    graph = OrderGraph()
    stamp = LockMonitor(BLESSED_LOCK_ORDER[2], threading.Lock(), graph,
                        enforce=True)
    engine = LockMonitor(BLESSED_LOCK_ORDER[0], threading.RLock(), graph,
                         enforce=True)
    with engine:
        with stamp:  # forward: engine -> stamp is blessed
            pass
    with stamp:
        with pytest.raises(LockOrderError):
            with engine:  # backwards: stamp -> engine
                pass


def test_runtime_monitor_agrees_with_static_graph_under_stress():
    static = {(e.held, e.acquired) for e in analyze_locks().edges}
    srv = _server(threaded=True)
    graph = monitor_server(srv)
    try:
        streams = [
            list(zipf_queries(ROWS, 24, 5.0, seed=100 + p))
            for p in range(3)
        ]
        errs = []

        def body(idx):
            try:
                for i, q in enumerate(streams[idx]):
                    srv.submit("a" if i % 2 == 0 else "b", q,
                               producer=f"p{idx}")
            except Exception as e:  # pragma: no cover - surfaced below
                errs.append(e)

        threads = [threading.Thread(target=body, args=(i,), daemon=True)
                   for i in range(len(streams))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        srv.drain()
        assert not errs
    finally:
        srv.close()
    # every observed acquisition ran forward in the blessed order...
    assert graph.check_blessed() == []
    assert graph.cycles() == []
    assert graph.edge_set(), "stress must exercise nested acquisitions"
    # ...and never contradicts the static over-approximation (the
    # static pass may see more edges than one schedule exercises, but
    # an observed REVERSE of a static edge would be a deadlock pair)
    for held, acquired in graph.edge_set():
        assert (acquired, held) not in static, (held, acquired)


def test_report_closed_flag_is_locked_snapshot():
    # regression: report() used to read ``_closed`` without the stamp
    # lock that guards every write to it — the analyzer flagged it and
    # the read now goes through _snapshot_closed(); reverting that fix
    # also re-fails test_static_lock_pass_blesses_current_tree
    srv = _server(threaded=False)
    try:
        assert srv.report()["scheduler"]["closed"] is False
    finally:
        srv.close()
    assert srv.report()["scheduler"]["closed"] is True


def test_flush_holds_engine_lock_against_concurrent_submit():
    # regression: a user-called flush() used to walk ``_buffer`` without
    # the engine lock, racing a concurrent global-mode submit(); with
    # the lock no submitted row may be dropped or double-served
    srv = _server(threaded=False, flush_policy="global")
    try:
        rng = np.random.default_rng(7)
        stop = threading.Event()
        errs = []

        def flusher():
            try:
                while not stop.is_set():
                    srv.flush()
            except Exception as e:  # pragma: no cover - surfaced below
                errs.append(e)

        t = threading.Thread(target=flusher, daemon=True)
        t.start()
        for _ in range(32):
            srv.submit("a", rng.integers(0, ROWS, size=4))
        stop.set()
        t.join()
        srv.flush()
        assert not errs
        # every submitted query served exactly once: a racy flush walk
        # would drop or double-serve rows and skew this counter
        assert srv.stats.queries == 32
    finally:
        srv.close()


# --------------------------------------------------------------- lint --


def test_repo_lint_runs_clean():
    assert [str(f) for f in run_lint()] == []


def test_lint_catches_each_crafted_violation(tmp_path):
    src = tmp_path / "src"
    (src / "repro" / "serve").mkdir(parents=True)
    (src / "mod_rand.py").write_text(
        "import numpy as np\n"
        "def f():\n"
        "    return np.random.rand(3)\n"
    )
    (src / "mod_pack.py").write_text(
        "def g(a, b, n):\n"
        "    key = a * n + b\n"
        "    return key\n"
    )
    (src / "repro" / "serve" / "decode.py").write_text(
        "import time\n"
        "def merge_order():\n"
        "    return time.time()\n"
    )
    (src / "mod_mut.py").write_text(
        "def h(patch):\n"
        "    patch.promoted.append(1)\n"
    )
    (src / "mod_oracle.py").write_text(
        "def _reference_unused():\n"
        "    return 0\n"
    )
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests" / "test_none.py").write_text("def test_ok(): pass\n")

    rules = {f.rule for f in run_lint(tmp_path)}
    assert {"unseeded-random", "packed-key-guard", "wall-clock",
            "patch-mutation", "oracle-coverage",
            "docstring-coverage"} <= rules


def test_lint_packed_key_guard_accepts_guarded_module(tmp_path):
    src = tmp_path / "src"
    src.mkdir()
    (src / "mod_ok.py").write_text(
        "def _check_pair_key_capacity(n):\n"
        "    if n * n >= 1 << 63:\n"
        "        raise OverflowError(n)\n"
        "def g(a, b, n):\n"
        "    _check_pair_key_capacity(n)\n"
        "    key = a * n + b\n"
        "    return key\n"
    )
    assert run_lint(tmp_path) == []
