"""Online replanning (DESIGN.md §6): an incrementally patched plan must
serve BIT-IDENTICAL outputs to a from-scratch ``plan_shards`` rebuild on
the drifted frequencies, while moving only the promoted groups' tiles.

Bit-identity is pinned on integer-valued float tables (every partial sum
exact in f32), so what the tests reject is a wrong, dropped or
double-counted activation after a patch — the failure modes of a broken
ownership edit.  The protocol invariants come straight from DESIGN.md
§6: the patched replicated set equals the fresh Eq.-1 set, the patch
DMAs exactly ``Σ_promoted copies·(S-1)`` tiles (demotions DMA nothing),
and a no-drift serving window stages zero patches.
"""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (
    build_cooccurrence,
    build_layout,
    compile_queries,
    correlation_aware_grouping,
    fused_group_loads,
    plan_replication,
    shard_block_queries,
)
from repro.core.reduction import reduce_dense_oracle
from repro.data import zipf_queries
from repro.dist import (
    apply_plan_patch,
    build_fused_image,
    compute_plan_patch,
    plan_shards,
)
from repro.kernels import crossbar_reduce_sharded, patch_shard_images
from repro.serve.drift import DriftTracker, ReplanConfig

EQ1_BATCH = 64


def _int_table(rows, dim, seed):
    """Integer-valued f32 table: partial sums are exact in float32."""
    return np.random.default_rng(seed).integers(
        -8, 9, size=(rows, dim)
    ).astype(np.float32)


def _pipeline(rows, hist, *, group_size=16, dim=128):
    g = build_cooccurrence(hist, rows)
    grouping = correlation_aware_grouping(g, group_size)
    plan = plan_replication(grouping, g.freq, EQ1_BATCH)
    layout = build_layout(grouping, plan, dim)
    return layout, plan, grouping.group_freq(g.freq)


def _assert_valid_partition(sp):
    """Every tile owned by exactly one shard or resident on all of them."""
    S = sp.num_shards
    for t in range(sp.num_tiles):
        holders = int((sp.local_tile_of[:, t] >= 0).sum())
        if sp.shard_of_tile[t] < 0:
            assert holders == S, (t, holders)
        else:
            assert holders == 1, (t, holders)
            assert sp.local_tile_of[sp.shard_of_tile[t], t] >= 0
    for s in range(S):
        slots = sp.local_tile_of[s][sp.local_tile_of[s] >= 0]
        assert len(set(slots.tolist())) == slots.size, "slot collision"
        assert int((sp.local_tile_of[s] >= 0).sum()) == sp.local_num_tiles[s]


# --------------------------------------------------- patch ≡ rebuild --


@given(st.integers(0, 200), st.sampled_from([1, 2, 4]))
@settings(max_examples=6, deadline=None)
def test_patched_plan_serves_bit_identical_to_fresh_rebuild(seed, num_shards):
    rows, dim = 192, 128
    hist = zipf_queries(rows, 48, 6.0, seed=seed)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, seed)
    fused = build_fused_image([layout], [table])
    sp = plan_shards([layout], [plan], num_shards, group_freqs=[gfreq])
    images = jnp.asarray(sp.build_shard_images(fused))

    # drift: the hot set rotates onto formerly-cold groups (reversed
    # hotness is the worst case for a stale plan)
    dload = sp.group_load[::-1].copy()
    patch = compute_plan_patch(
        sp, dload, eq1_batch=EQ1_BATCH, capacity=int(images.shape[1])
    )
    sp_patched = apply_plan_patch(sp, patch)
    images_patched = patch_shard_images(images, patch, fused)
    _assert_valid_partition(sp_patched)

    fresh = plan_shards(
        [layout], [plan], num_shards, group_freqs=[dload], eq1_batch=EQ1_BATCH
    )
    # patched replication classes == what Eq. 1 on the drifted load says
    np.testing.assert_array_equal(sp_patched.replicated_group,
                                  fresh.replicated_group)
    # the patch DMAs exactly the promoted groups' tiles, never the image
    want_dma = sum(
        int(sp.group_copies[g]) * (num_shards - 1) for g in patch.promoted
    )
    assert patch.num_moved_tiles == want_dma
    assert patch.num_moved_tiles < int(fresh.local_num_tiles.sum())

    ev = zipf_queries(rows, 10 + seed % 7, 6.0, seed=seed + 1)
    cq = compile_queries(layout, ev, replica_block=4)
    images_fresh = jnp.asarray(fresh.build_shard_images(fused))
    sbq_p = shard_block_queries(cq, sp_patched, 4)
    sbq_f = shard_block_queries(cq, fresh, 4)
    out_p = np.asarray(crossbar_reduce_sharded(
        images_patched, sbq_p.tile_ids, sbq_p.bitmaps, combine_chunks=2
    ))[: sbq_p.batch]
    out_f = np.asarray(crossbar_reduce_sharded(
        images_fresh, sbq_f.tile_ids, sbq_f.bitmaps, combine_chunks=2
    ))[: sbq_f.batch]
    np.testing.assert_array_equal(out_p, out_f)
    oracle = np.asarray(reduce_dense_oracle(jnp.asarray(table), ev))
    np.testing.assert_array_equal(out_p, oracle)


def test_repeated_patches_stay_consistent():
    """Patch → drift again → patch: slot reuse, growth and re-promotion
    of a previously-demoted group must keep the partition valid and the
    numerics exact."""
    rows, dim, S = 192, 128, 2
    hist = zipf_queries(rows, 48, 6.0, seed=3)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, 3)
    fused = build_fused_image([layout], [table])
    sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
    images = jnp.asarray(sp.build_shard_images(fused))
    ev = zipf_queries(rows, 9, 6.0, seed=4)
    cq = compile_queries(layout, ev, replica_block=4)
    oracle = np.asarray(reduce_dense_oracle(jnp.asarray(table), ev))

    loads = [sp.group_load[::-1].copy(),
             np.roll(sp.group_load, sp.num_groups // 3),
             sp.group_load.copy()]          # back to the original hotness
    for dload in loads:
        patch = compute_plan_patch(
            sp, dload, eq1_batch=EQ1_BATCH, capacity=int(images.shape[1])
        )
        sp = apply_plan_patch(sp, patch)
        images = patch_shard_images(images, patch, fused)
        _assert_valid_partition(sp)
        sbq = shard_block_queries(cq, sp, 4)
        out = np.asarray(crossbar_reduce_sharded(
            images, sbq.tile_ids, sbq.bitmaps
        ))[: sbq.batch]
        np.testing.assert_array_equal(out, oracle)


def test_patch_demotion_moves_no_tiles():
    """A drift that only cools groups (promotes nothing) must DMA zero
    tiles: every shard already holds a replicated group's tiles."""
    rows = 192
    hist = zipf_queries(rows, 48, 6.0, seed=5)
    layout, plan, gfreq = _pipeline(rows, hist)
    sp = plan_shards([layout], [plan], 2, group_freqs=[gfreq])
    if not sp.replicated_group.any():
        return  # nothing replicated at this seed; vacuous
    flat = np.full(sp.num_groups, 1.0)  # uniform: Eq. 1 replicates nothing
    patch = compute_plan_patch(sp, flat, eq1_batch=EQ1_BATCH)
    assert len(patch.promoted) == 0
    assert len(patch.demoted) == int(sp.replicated_group.sum())
    assert patch.num_moved_tiles == 0
    _assert_valid_partition(apply_plan_patch(sp, patch))


def test_rescaled_load_restores_scale_sensitive_promotions():
    """Eq. 1 is not scale-invariant: a decayed serve-time estimate
    (orders below training mass) must be rescaled to the training total
    or hot-set rotations under-promote.  The rescaled tiny observation
    must produce the same replication classes as the full-scale load."""
    from repro.dist import rescale_load_to_plan

    rows = 192
    hist = zipf_queries(rows, 48, 6.0, seed=13)
    layout, plan, gfreq = _pipeline(rows, hist)
    sp = plan_shards([layout], [plan], 2, group_freqs=[gfreq])
    dload_full = sp.group_load[::-1].copy()
    dload_tiny = dload_full / 512.0        # tracker-magnitude estimate
    patch_full = compute_plan_patch(sp, dload_full, eq1_batch=EQ1_BATCH)
    rescaled = rescale_load_to_plan(
        dload_tiny, sp, [sp.group_load.sum()]
    )
    np.testing.assert_allclose(rescaled, dload_full)
    patch_rescaled = compute_plan_patch(sp, rescaled, eq1_batch=EQ1_BATCH)
    assert patch_rescaled.promoted == patch_full.promoted
    assert patch_rescaled.demoted == patch_full.demoted
    # the raw tiny load under-promotes whenever anything is promotable
    patch_raw = compute_plan_patch(sp, dload_tiny, eq1_batch=EQ1_BATCH)
    assert len(patch_raw.promoted) <= len(patch_full.promoted)


def test_build_shard_images_scatters_to_holey_slots():
    """Rebuilding images from a patched plan (checkpoint/restart path)
    must scatter tiles to their allocated local slots, not compact them
    to 0..n-1 — a demote-only patch leaves holes in the numbering."""
    rows, dim, S = 192, 128, 2
    hist = zipf_queries(rows, 48, 6.0, seed=7)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, 7)
    fused = build_fused_image([layout], [table])
    sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
    if not sp.replicated_group.any():
        return  # vacuous at this seed
    flat = np.full(sp.num_groups, 1.0)  # demotes everything replicated
    patch = compute_plan_patch(sp, flat, eq1_batch=EQ1_BATCH)
    sp2 = apply_plan_patch(sp, patch)
    assert any(
        (sp2.local_tile_of[s][sp2.local_tile_of[s] >= 0].max(initial=-1) + 1)
        > sp2.local_num_tiles[s]
        for s in range(S)
    ), "patch left no holes; test needs a demotion"
    rebuilt = sp2.build_shard_images(fused)
    for s in range(S):
        for t in np.nonzero(sp2.local_tile_of[s] >= 0)[0]:
            np.testing.assert_array_equal(
                rebuilt[s, sp2.local_tile_of[s, t]], fused[t]
            )
    # and serving through the rebuilt stack stays exact
    ev = zipf_queries(rows, 9, 6.0, seed=8)
    cq = compile_queries(layout, ev, replica_block=4)
    sbq = shard_block_queries(cq, sp2, 4)
    out = np.asarray(crossbar_reduce_sharded(
        jnp.asarray(rebuilt), sbq.tile_ids, sbq.bitmaps
    ))[: sbq.batch]
    oracle = np.asarray(reduce_dense_oracle(jnp.asarray(table), ev))
    np.testing.assert_array_equal(out, oracle)


def test_noop_patch_rebases_load_only():
    sp_rows = 192
    hist = zipf_queries(sp_rows, 48, 6.0, seed=9)
    layout, plan, gfreq = _pipeline(sp_rows, hist)
    sp = plan_shards([layout], [plan], 2, group_freqs=[gfreq])
    wobble = sp.group_load * 1.5  # same ordering → same Eq.-1 classes
    patch = compute_plan_patch(sp, wobble, eq1_batch=EQ1_BATCH)
    assert patch.is_noop() and patch.num_moved_tiles == 0
    sp2 = apply_plan_patch(sp, patch)
    np.testing.assert_array_equal(sp2.shard_of_tile, sp.shard_of_tile)
    np.testing.assert_array_equal(sp2.local_tile_of, sp.local_tile_of)
    np.testing.assert_array_equal(sp2.group_load, wobble)


# --------------------------------------- demotion target: tile pressure --


def test_cold_demotion_lands_on_least_tile_loaded_shard():
    """A demoted group has usually COOLED to ~zero load, where frequency
    balance says nothing — the owner choice must fall back to per-shard
    tile pressure (cold-tail memory balance), the fresh planner's rule.
    Scenario: shard 0 has few hot tiles but high load, shard 1 one hot
    tile with all the load; the frequency-only rule would dump the cold
    group on the least-loaded shard regardless of its tile count."""
    from repro.dist.shard_plan import ShardPlan, TableSegment

    # g0 replicated (1 tile), g1 (2 tiles, load 1)→s0, g2 (1 tile,
    # load 1)→s0, g3 (1 tile, load 20)→s1: s0 = 3 tiles / load 2,
    # s1 = 1 tile / load 20.
    copies = np.array([1, 2, 1, 1], dtype=np.int64)
    local = np.array([
        [0, 1, 2, 3, -1],
        [0, -1, -1, -1, 1],
    ], dtype=np.int32)
    sp = ShardPlan(
        num_shards=2,
        tables=[TableSegment("t0", 0, 0, 4, 5, 16)],
        replicated_group=np.array([True, False, False, False]),
        shard_of_group=np.array([-1, 0, 0, 1], dtype=np.int32),
        shard_of_tile=np.array([-1, 0, 0, 0, 1], dtype=np.int32),
        local_tile_of=local,
        local_num_tiles=np.array([4, 2], dtype=np.int64),
        group_load=np.array([30.0, 1.0, 1.0, 20.0]),
        group_copies=copies,
    )
    # g0 cools to zero; eq1_batch=2 keeps every other class unchanged
    dload = np.array([0.0, 1.0, 1.0, 20.0])
    patch = compute_plan_patch(sp, dload, eq1_batch=2)
    assert patch.promoted == []
    # least-load would be shard 0 (2 < 20); least-tile is shard 1 (1 < 3)
    assert patch.demoted == [(0, 1)], patch.demoted
    _assert_valid_partition(apply_plan_patch(sp, patch))


def test_loaded_demotion_still_balances_by_frequency():
    """A demoted group that kept real load places on the least-LOADED
    shard (tile pressure only breaks ties) — same rule as plan_shards."""
    from repro.dist.shard_plan import ShardPlan, TableSegment

    copies = np.array([1, 2, 1, 1], dtype=np.int64)
    local = np.array([
        [0, 1, 2, 3, -1],
        [0, -1, -1, -1, 1],
    ], dtype=np.int32)
    sp = ShardPlan(
        num_shards=2,
        tables=[TableSegment("t0", 0, 0, 4, 5, 16)],
        replicated_group=np.array([True, False, False, False]),
        shard_of_group=np.array([-1, 0, 0, 1], dtype=np.int32),
        shard_of_tile=np.array([-1, 0, 0, 0, 1], dtype=np.int32),
        local_tile_of=local,
        local_num_tiles=np.array([4, 2], dtype=np.int64),
        group_load=np.array([30.0, 1.0, 1.0, 20.0]),
        group_copies=copies,
    )
    # g0 keeps real load (5.0) but drops out of the Eq.-1 replicated
    # set: owner = least-loaded shard 0 (load 2 < 20), tiles be damned
    dload = np.array([5.0, 1.0, 1.0, 20.0])
    patch = compute_plan_patch(sp, dload, eq1_batch=2)
    assert patch.demoted == [(0, 0)], patch.demoted


# ----------------------------------------------- slack capacity age-out --


def test_shrink_slack_ages_out_free_capacity():
    """After demotions, shrink_slack compacts the stack down to the
    busiest shard's resident count + headroom: tiles above the new
    depth relocate into freed holes, patch_shard_images slices, and
    serving stays exact through the shrunk stack."""
    rows, dim, S = 192, 128, 2
    hist = zipf_queries(rows, 48, 6.0, seed=3)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, 3)
    fused = build_fused_image([layout], [table])
    sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
    if not sp.replicated_group.any():
        return  # vacuous at this seed
    slack = 8
    images = jnp.asarray(sp.build_shard_images(fused))
    pad = jnp.zeros((S, slack) + images.shape[2:], images.dtype)
    images = jnp.concatenate([images, pad], axis=1)
    capacity = int(images.shape[1])

    flat = np.full(sp.num_groups, 1.0)  # demotes everything replicated
    # without shrink: capacity sticks at the high-water mark
    keep = compute_plan_patch(sp, flat, eq1_batch=EQ1_BATCH, capacity=capacity)
    assert keep.new_capacity == capacity
    assert keep.num_relocated_tiles == 0
    # with shrink: the stack compacts to working set + headroom
    patch = compute_plan_patch(
        sp, flat, eq1_batch=EQ1_BATCH, capacity=capacity, shrink_slack=2
    )
    sp2 = apply_plan_patch(sp, patch)
    assert patch.new_capacity < capacity
    assert patch.new_capacity == int(sp2.local_num_tiles.max()) + 2
    assert sp2.max_local_tiles <= patch.new_capacity
    images2 = patch_shard_images(images, patch, fused)
    assert images2.shape[1] == patch.new_capacity
    _assert_valid_partition(sp2)

    ev = zipf_queries(rows, 9, 6.0, seed=4)
    cq = compile_queries(layout, ev, replica_block=4)
    sbq = shard_block_queries(cq, sp2, 4)
    out = np.asarray(crossbar_reduce_sharded(
        images2, sbq.tile_ids, sbq.bitmaps
    ))[: sbq.batch]
    oracle = np.asarray(reduce_dense_oracle(jnp.asarray(table), ev))
    np.testing.assert_array_equal(out, oracle)


def test_rebase_with_relocations_is_not_noop():
    """A class-unchanged drift computed WITH shrink_slack may still
    relocate resident tiles (compaction).  Such a patch must NOT be
    treated as a load rebase — applying the plan without the image
    update would read zeros from the tiles' new slots."""
    rows, dim, S = 192, 128, 2
    hist = zipf_queries(rows, 48, 6.0, seed=3)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, 3)
    fused = build_fused_image([layout], [table])
    sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
    if not sp.replicated_group.any():
        return  # vacuous at this seed
    images = jnp.asarray(sp.build_shard_images(fused))
    # demote-all first: leaves holes below top-slot residents
    flat = np.full(sp.num_groups, 1.0)
    p1 = compute_plan_patch(sp, flat, eq1_batch=EQ1_BATCH,
                            capacity=int(images.shape[1]))
    sp = apply_plan_patch(sp, p1)
    images = patch_shard_images(images, p1, fused)
    # class-unchanged wobble + shrink: compaction relocates tiles
    p2 = compute_plan_patch(
        sp, flat * 1.5, eq1_batch=EQ1_BATCH,
        capacity=int(images.shape[1]), shrink_slack=0,
    )
    assert not p2.promoted and not p2.demoted
    if not p2.moved:
        return  # nothing above the compaction target; vacuous
    assert not p2.is_noop(), "relocation-carrying patch treated as rebase"
    sp2 = apply_plan_patch(sp, p2)
    images2 = patch_shard_images(images, p2, fused)
    _assert_valid_partition(sp2)
    ev = zipf_queries(rows, 9, 6.0, seed=4)
    cq = compile_queries(layout, ev, replica_block=4)
    sbq = shard_block_queries(cq, sp2, 4)
    out = np.asarray(crossbar_reduce_sharded(
        images2, sbq.tile_ids, sbq.bitmaps
    ))[: sbq.batch]
    oracle = np.asarray(reduce_dense_oracle(jnp.asarray(table), ev))
    np.testing.assert_array_equal(out, oracle)


def test_server_shrink_streak_reclaims_image_capacity():
    """The serving driver's demotion-streak trigger: once the streak
    reaches shrink_streak, the next demotion-only patch also compacts
    the image stack back to working set + slack, and slack_slots
    reports the residual headroom."""
    from repro.serve import ShardedEmbeddingServer

    # 320 rows / 20 groups: uniform traffic gives every group too small
    # a share for Eq. 1 to promote (log f/log f_total · log B < 1), so
    # the drift patch is demotion-only and the streak machinery engages
    rows, dim = 320, 128
    tables = {"a": _int_table(rows, dim, 21)}
    histories = {"a": zipf_queries(rows, 64, 5.0, seed=22)}
    server = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=8,
        replan=ReplanConfig(threshold=0.2, half_life=2.0, min_queries=8,
                            slack_tiles=4, shrink_streak=1),
    )
    if not server.plan.replicated_group.any():
        return  # nothing to demote; vacuous
    cap_before = int(server.shard_images.shape[1])
    server._demote_streak = 1  # as if a demotion-only patch already landed
    rng = np.random.default_rng(99)
    stream = [rng.choice(rows, size=24, replace=False).tolist()
              for _ in range(48)]
    results = []
    for chunk in range(0, len(stream), 8):
        out = server.serve({"a": stream[chunk : chunk + 8]})
        results.append(np.asarray(out["a"]))
    assert server.stats.replans >= 1, server.stats
    assert server.stats.promoted_groups == 0, server.stats
    cap_after = int(server.shard_images.shape[1])
    assert cap_after < cap_before, (cap_before, cap_after)
    rep = server.report()
    assert rep["replan"]["slack_slots"] <= server.replan_cfg.slack_tiles
    # serving through the shrunk stack stays exact
    got = np.concatenate(results)
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------ drift tracker --


def test_drift_tracker_statistic():
    base = np.array([8.0, 4.0, 2.0, 1.0])
    tr = DriftTracker(base, half_life=1.0, min_queries=4)
    assert not tr.ready
    assert tr.drift_from(base) == 0.0
    # identical-distribution observations keep drift at zero
    tr.observe(base * 2, num_queries=4)
    assert tr.ready
    assert abs(tr.drift_from(base)) < 1e-12
    # rotate all mass to the cold tail: drift climbs toward TV distance 1
    for _ in range(12):
        tr.observe(np.array([0.0, 0.0, 0.0, 30.0]), num_queries=4)
    assert tr.drift_from(base) > 0.7
    # zero-mass reference yields no signal
    assert tr.drift_from(np.zeros(4)) == 0.0


def test_fused_group_loads_matches_row_semantics():
    rows = 160
    hist = zipf_queries(rows, 40, 5.0, seed=11)
    layout, plan, gfreq = _pipeline(rows, hist)
    sp = plan_shards([layout], [plan], 2, group_freqs=[gfreq])
    ev = zipf_queries(rows, 12, 5.0, seed=12)
    cq = compile_queries(layout, ev, replica_block=4)
    tile_group = np.repeat(np.arange(sp.num_groups), sp.group_copies)
    got = fused_group_loads(cq, tile_group, sp.num_groups)
    want = np.zeros(sp.num_groups)
    for q in ev:
        rows_u = np.unique(np.asarray(q, dtype=np.int64))
        np.add.at(want, layout.group_of[rows_u], 1.0)
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------- serving driver --


def _drifting_server(threshold=0.2, **kw):
    from repro.serve import ShardedEmbeddingServer

    rows, dim = 128, 128
    tables = {"a": _int_table(rows, dim, 21)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=22)}
    server = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=8,
        replan=ReplanConfig(threshold=threshold, half_life=1.0,
                            min_queries=8, slack_tiles=4),
        **kw,
    )
    return server, tables, rows


def test_server_replans_under_drift_and_stays_exact():
    server, tables, rows = _drifting_server()
    stream = zipf_queries(rows, 40, 5.0, seed=23)
    perm = np.random.default_rng(24).permutation(rows)
    stream = stream[:16] + [perm[np.asarray(q, np.int64)] for q in stream[16:]]
    results = []
    for q in stream:
        out = server.submit("a", q)
        if out:
            results.append(out["a"])
    tail = server.flush()
    if tail:
        results.append(tail["a"])
    rep = server.report()
    assert rep["serve"]["replans"] + rep["serve"]["rebases"] >= 1, rep["serve"]
    # every flush's outputs — across plan swaps — match the dense oracle
    got = np.concatenate([np.asarray(r) for r in results])
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(got, want)
    # a patch never rewrites the image: DMA'd tiles stay below residency
    assert rep["serve"]["patched_tiles"] < rep["plan"]["stored_tiles"] * max(
        rep["serve"]["replans"], 1
    )


def test_server_no_drift_window_applies_zero_patches():
    """Serving the training distribution itself must never patch."""
    server, tables, rows = _drifting_server(threshold=0.25)
    # replay the history the plan was built from — zero distribution shift
    for q in server_history(server):
        server.submit("a", q)
    server.flush()
    rep = server.report()
    assert rep["serve"]["replans"] == 0
    assert rep["serve"]["patched_tiles"] == 0
    assert rep["replan"]["staged"] is None
    assert rep["replan"]["drift"] < 0.25


def server_history(server):
    # the exact trace the offline pipeline saw (seed 22 above)
    return zipf_queries(128, 48, 5.0, seed=22)


def test_idle_table_registers_no_drift():
    """Multi-table: a table that simply receives no traffic must not
    register as standing drift (its segment's decayed estimate is a
    scaled copy of its reference) — only its own distribution moving
    counts.  Guards against every-flush false rebases."""
    from repro.serve import ShardedEmbeddingServer

    rows, dim = 128, 128
    tables = {"a": _int_table(rows, dim, 31), "b": _int_table(rows, dim, 32)}
    histories = {
        "a": zipf_queries(rows, 48, 5.0, seed=33),
        "b": zipf_queries(rows, 48, 5.0, seed=34),
    }
    server = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=8,
        replan=ReplanConfig(threshold=0.2, half_life=1.0, min_queries=8),
    )
    # replay table a's own training history; table b stays idle
    for q in histories["a"][:32]:
        server.submit("a", q)
    server.flush()
    rep = server.report()
    assert rep["serve"]["replans"] == 0, rep["serve"]
    assert rep["serve"]["rebases"] == 0, rep["serve"]
    assert rep["replan"]["drift"] < 0.2, rep["replan"]


def test_server_report_exposes_replan_state():
    server, _, rows = _drifting_server()
    rep = server.report()
    assert rep["replan"]["drift"] == 0.0
    assert rep["replan"]["ready"] is False
    assert rep["replan"]["staged"] is None
    server.serve({"a": zipf_queries(rows, 4, 5.0, seed=30)})
    assert server.report()["replan"]["observed_queries"] == 4


def test_shard_map_branch_serves_patched_plan_subprocess():
    """The REAL shard_map path must serve a patched plan + patched image
    bit-identically to the emulation path and the fresh rebuild.  Device
    forcing must precede jax init → subprocess with 2 host devices."""
    import os
    import subprocess
    import sys

    script = r"""
import numpy as np
import jax, jax.numpy as jnp
assert len(jax.devices()) >= 2, jax.devices()
import sys
sys.path.insert(0, {src!r})
from repro.core import (build_cooccurrence, build_layout, compile_queries,
                        correlation_aware_grouping, plan_replication,
                        shard_block_queries)
from repro.data import zipf_queries
from repro.dist import (apply_plan_patch, build_fused_image,
                        compute_plan_patch, plan_shards)
from repro.kernels import crossbar_reduce_sharded, patch_shard_images

rows, dim, S = 96, 128, 2
hist = zipf_queries(rows, 32, 5.0, seed=1)
ev = zipf_queries(rows, 9, 5.0, seed=2)
g = build_cooccurrence(hist, rows)
grouping = correlation_aware_grouping(g, 16)
plan = plan_replication(grouping, g.freq, 32)
layout = build_layout(grouping, plan, dim)
gfreq = grouping.group_freq(g.freq)
table = np.random.default_rng(3).integers(-8, 9, size=(rows, dim)).astype(np.float32)
fused = build_fused_image([layout], [table])
sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
dload = sp.group_load[::-1].copy()
patch = compute_plan_patch(sp, dload, eq1_batch=32)
sp2 = apply_plan_patch(sp, patch)
images2 = patch_shard_images(jnp.asarray(sp.build_shard_images(fused)), patch, fused)
fresh = plan_shards([layout], [plan], S, group_freqs=[dload], eq1_batch=32)
images_f = jnp.asarray(fresh.build_shard_images(fused))
cq = compile_queries(layout, ev, replica_block=4)
sbq2 = shard_block_queries(cq, sp2, 4)
sbqf = shard_block_queries(cq, fresh, 4)
emu = np.asarray(crossbar_reduce_sharded(images2, sbq2.tile_ids, sbq2.bitmaps,
                                         combine_chunks=2))
mesh = jax.make_mesh((1, S), ("data", "model"))
for combine in ("psum_scatter", "psum"):
    sm = np.asarray(crossbar_reduce_sharded(
        images2, sbq2.tile_ids, sbq2.bitmaps, mesh=mesh,
        combine=combine, combine_chunks=2))
    np.testing.assert_array_equal(sm, emu)
smf = np.asarray(crossbar_reduce_sharded(
    images_f, sbqf.tile_ids, sbqf.bitmaps, mesh=mesh, combine_chunks=2))
np.testing.assert_array_equal(smf, emu)
print("REPLAN_SHARD_MAP_PARITY_OK")
""".format(src=os.path.join(os.path.dirname(__file__), "..", "src"))

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=2 "
        + env.get("XLA_FLAGS", "")
    )
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=480,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "REPLAN_SHARD_MAP_PARITY_OK" in proc.stdout
