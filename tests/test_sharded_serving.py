"""Sharded multi-table serving: the shard-local reduction + cross-shard
combine must be BIT-IDENTICAL to the single-device flat ``crossbar_reduce``
reference for every shard count, including padding tiles, ragged batches
and the dynamic-switch READ path.

Bit-identity is pinned on integer-valued float tables: every partial sum
is exactly representable, so any associativity-only difference between
the sharded combine and the flat accumulator would still compare equal —
what the test rejects is a *wrong or double-counted activation*, the
actual failure mode of a bad ownership split.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (
    build_cooccurrence,
    build_layout,
    compile_queries,
    concat_compiled_queries,
    correlation_aware_grouping,
    offset_compiled_queries,
    plan_replication,
    shard_block_queries,
)
from repro.core.reduction import reduce_dense_oracle
from repro.data import zipf_queries
from repro.dist import build_fused_image, plan_shards
from repro.kernels import (
    combine_bytes_per_batch,
    crossbar_reduce,
    crossbar_reduce_sharded,
    crossbar_reduce_tables,
)


def _int_table(rows, dim, seed):
    """Integer-valued f32 table: partial sums are exact in float32."""
    return np.random.default_rng(seed).integers(
        -8, 9, size=(rows, dim)
    ).astype(np.float32)


def _pipeline(rows, hist, *, group_size=16, dim=128, batch_size=64):
    g = build_cooccurrence(hist, rows)
    grouping = correlation_aware_grouping(g, group_size)
    plan = plan_replication(grouping, g.freq, batch_size)
    layout = build_layout(grouping, plan, dim)
    return layout, plan, grouping.group_freq(g.freq)


def _sharded_setup(seed, batch, num_shards, *, q_block=4, rows=192, dim=128):
    hist = zipf_queries(rows, 48, 6.0, seed=seed)
    ev = zipf_queries(rows, batch, 6.0, seed=seed + 1)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, seed)
    fused = build_fused_image([layout], [table])
    sp = plan_shards([layout], [plan], num_shards, group_freqs=[gfreq])
    cq = compile_queries(layout, ev, replica_block=q_block)
    sbq = shard_block_queries(cq, sp, q_block)
    images = jnp.asarray(sp.build_shard_images(fused))
    flat = crossbar_reduce(
        jnp.asarray(fused), cq.tile_ids, cq.bitmaps
    )
    return images, sbq, flat, table, ev, sp, cq


# ------------------------------------------------------------ planner --


def test_plan_partitions_every_tile_exactly_once():
    hist = zipf_queries(128, 40, 5.0, seed=3)
    layout, plan, gfreq = _pipeline(128, hist)
    for S in (1, 2, 4):
        sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
        # every tile either owned by one shard or replicated on all
        for t in range(sp.num_tiles):
            holders = (sp.local_tile_of[:, t] >= 0).sum()
            if sp.shard_of_tile[t] < 0:
                assert holders == S
            else:
                assert holders == 1
        # local numbering is dense per shard
        for s in range(S):
            local = sp.local_tile_of[s][sp.local_tile_of[s] >= 0]
            assert sorted(local.tolist()) == list(range(sp.local_num_tiles[s]))
        # replica tiles of a sharded group stay together
        tile_group = np.repeat(
            np.arange(layout.num_groups), layout.copies
        )
        for g in range(layout.num_groups):
            owners = np.unique(sp.shard_of_tile[tile_group == g])
            assert owners.size == 1


def test_plan_is_deterministic_and_balanced():
    hist = zipf_queries(256, 64, 8.0, seed=7)
    layout, plan, gfreq = _pipeline(256, hist)
    a = plan_shards([layout], [plan], 4, group_freqs=[gfreq])
    b = plan_shards([layout], [plan], 4, group_freqs=[gfreq])
    np.testing.assert_array_equal(a.shard_of_group, b.shard_of_group)
    # greedy (descending-load, least-loaded-first) balance bound: no
    # shard exceeds the fair share by more than one group's load
    sharded = ~a.replicated_group
    if sharded.any():
        loads = np.zeros(4)
        np.add.at(loads, a.shard_of_group[sharded], a.group_load[sharded])
        fair = a.group_load[sharded].sum() / 4 + a.group_load[sharded].max()
        assert loads.max() <= fair, (loads, fair)
    # the zero-load cold tail must balance on TILES, not pile onto the
    # least-loaded shard: with all-zero loads the owned tile counts may
    # differ by at most one group's replica set
    cold = plan_shards(
        [layout], [plan], 4,
        group_freqs=[np.zeros(layout.num_groups)],
    )
    owned = np.zeros(4, dtype=np.int64)
    for s in cold.shard_of_tile:
        if s >= 0:
            owned[s] += 1
    if owned.sum():
        assert owned.max() - owned.min() <= int(layout.copies.max()), owned


def test_shard_images_padding_tiles_are_zero():
    hist = zipf_queries(96, 32, 5.0, seed=11)
    layout, plan, gfreq = _pipeline(96, hist)
    sp = plan_shards([layout], [plan], 4, group_freqs=[gfreq])
    fused = build_fused_image([layout], [_int_table(96, 128, 11)])
    imgs = sp.build_shard_images(fused)
    for s in range(4):
        n = int(sp.local_num_tiles[s])
        assert (imgs[s, n:] == 0).all()


# ------------------------------------------- sharded reduction parity --


@given(st.integers(0, 200), st.sampled_from([1, 2, 4]))
@settings(max_examples=6, deadline=None)
def test_sharded_reduce_bit_identical_to_flat_reference(seed, num_shards):
    batch = 10 + seed % 7   # ragged: exercises q_block padding rows
    images, sbq, flat, table, ev, _, _ = _sharded_setup(seed, batch, num_shards)
    out = crossbar_reduce_sharded(
        images, sbq.tile_ids, sbq.bitmaps, combine_chunks=2
    )[: sbq.batch]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(flat))
    # and against the layout-independent dense oracle
    oracle = reduce_dense_oracle(jnp.asarray(table), ev)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


def test_sharded_reduce_padding_rows_are_zero():
    images, sbq, _, _, _, _, _ = _sharded_setup(5, 10, 2, q_block=4)
    out = np.asarray(crossbar_reduce_sharded(images, sbq.tile_ids, sbq.bitmaps))
    assert out.shape[0] == sbq.num_blocks * sbq.q_block
    assert (out[sbq.batch:] == 0).all()


def test_sharded_reduce_read_path_single_row_queries():
    """Single-row bags drive the dynamic-switch READ path on every shard;
    splitting a block across shards lowers per-shard popcounts, so the
    sharded kernel takes READ where the flat kernel took MAC — values
    must still agree exactly."""
    rows, dim = 128, 128
    hist = zipf_queries(rows, 40, 5.0, seed=21)
    ev = [[int(i)] for i in np.random.default_rng(21).integers(0, rows, 12)]
    ev += [[0, 1, 2, 3], []]
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, 21)
    fused = build_fused_image([layout], [table])
    cq = compile_queries(layout, ev, replica_block=4)
    flat = crossbar_reduce(jnp.asarray(fused), cq.tile_ids, cq.bitmaps)
    for S in (1, 2, 4):
        sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
        sbq = shard_block_queries(cq, sp, 4)
        images = jnp.asarray(sp.build_shard_images(fused))
        for dyn in (True, False):
            out = crossbar_reduce_sharded(
                images, sbq.tile_ids, sbq.bitmaps, dynamic_switch=dyn
            )[: sbq.batch]
            np.testing.assert_array_equal(np.asarray(out), np.asarray(flat))


def test_shard_ownership_covers_every_activation_once():
    """Summed over shards, the sharded bitmaps must equal the flat
    compiled bitmaps per (query, fused tile) — no drop, no double count."""
    images, sbq, _, _, ev, sp, cq = _sharded_setup(9, 12, 4, q_block=4)
    q_block = sbq.q_block
    bms = np.asarray(sbq.bitmaps)       # (S, nb, mt, q, rows)
    ids = np.asarray(sbq.tile_ids)      # (S, nb, mt)
    got = {}
    for s in range(sp.num_shards):
        local_to_global = {}
        for t in range(sp.num_tiles):
            if sp.local_tile_of[s, t] >= 0:
                local_to_global[int(sp.local_tile_of[s, t])] = t
        for n in range(sbq.num_blocks):
            for m in range(sbq.max_tiles):
                if ids[s, n, m] < 0:
                    continue
                g = local_to_global[int(ids[s, n, m])]
                for k in range(q_block):
                    q = n * q_block + k
                    if bms[s, n, m, k].any():
                        key = (q, g)
                        assert key not in got, "activation double-owned"
                        got[key] = bms[s, n, m, k]
    # compare against the flat compile the sharded batch was built from
    fids = np.asarray(cq.tile_ids)
    fbms = np.asarray(cq.bitmaps)
    want = {}
    for q in range(fids.shape[0]):
        for sl in range(fids.shape[1]):
            if fids[q, sl] >= 0 and fbms[q, sl].any():
                want[(q, int(fids[q, sl]))] = fbms[q, sl]
    assert set(got) == set(want)
    for key in want:
        np.testing.assert_array_equal(got[key], want[key])


def test_per_shard_grid_never_exceeds_single_device_grid():
    """The acceptance invariant: shard-local unions are subsets of the
    global union, so the per-shard padded grid must not exceed the
    single-device blocked grid."""
    from repro.core import block_compiled_queries

    for seed in (1, 13):
        hist = zipf_queries(256, 64, 8.0, seed=seed)
        ev = zipf_queries(256, 32, 8.0, seed=seed + 1)
        layout, plan, gfreq = _pipeline(256, hist, dim=128)
        cq = compile_queries(layout, ev, replica_block=8)
        bq = block_compiled_queries(cq, 8)
        flat_cells = bq.num_blocks * bq.max_tiles
        for S in (1, 2, 4):
            sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
            sbq = shard_block_queries(cq, sp, 8)
            assert sbq.grid_cells_per_shard() <= flat_cells
            assert int(np.max(sbq.shard_widths)) <= bq.max_tiles


def test_shard_map_branch_matches_emulation_subprocess():
    """The REAL shard_map branch (psum_scatter + all_gather, psum
    fallback, check_rep=False, out[0] selection) must be bit-identical
    to the emulation path.  Device forcing must precede jax init, so the
    parity check runs in a subprocess with 2 forced host devices."""
    import os
    import subprocess
    import sys

    script = r"""
import numpy as np
import jax, jax.numpy as jnp
assert len(jax.devices()) >= 2, jax.devices()
import sys
sys.path.insert(0, {src!r})
from repro.core import (build_cooccurrence, build_layout, compile_queries,
                        correlation_aware_grouping, plan_replication,
                        shard_block_queries)
from repro.data import zipf_queries
from repro.dist import build_fused_image, plan_shards
from repro.kernels import crossbar_reduce_sharded

rows, dim, S = 96, 128, 2
hist = zipf_queries(rows, 32, 5.0, seed=1)
ev = zipf_queries(rows, 9, 5.0, seed=2)   # ragged: pads to q_block
g = build_cooccurrence(hist, rows)
grouping = correlation_aware_grouping(g, 16)
plan = plan_replication(grouping, g.freq, 32)
layout = build_layout(grouping, plan, dim)
table = np.random.default_rng(3).integers(-8, 9, size=(rows, dim)).astype(np.float32)
fused = build_fused_image([layout], [table])
cq = compile_queries(layout, ev, replica_block=4)
sp = plan_shards([layout], [plan], S, group_freqs=[grouping.group_freq(g.freq)])
sbq = shard_block_queries(cq, sp, 4)
images = jnp.asarray(sp.build_shard_images(fused))
emu = np.asarray(crossbar_reduce_sharded(images, sbq.tile_ids, sbq.bitmaps,
                                         combine_chunks=2))
mesh = jax.make_mesh((1, S), ("data", "model"))
for combine in ("psum_scatter", "psum"):
    sm = np.asarray(crossbar_reduce_sharded(
        images, sbq.tile_ids, sbq.bitmaps, mesh=mesh,
        combine=combine, combine_chunks=2))
    np.testing.assert_array_equal(sm, emu)
print("SHARD_MAP_PARITY_OK")
""".format(src=os.path.join(os.path.dirname(__file__), "..", "src"))

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=2 "
        + env.get("XLA_FLAGS", "")
    )
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=480,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARD_MAP_PARITY_OK" in proc.stdout


# ------------------------------------------------------- multi-table --


def test_multi_table_fused_reduce_matches_oracles():
    rows_a, rows_b, dim = 160, 96, 128
    hist_a = zipf_queries(rows_a, 48, 6.0, seed=31)
    hist_b = zipf_queries(rows_b, 40, 4.0, seed=32)
    la, pa, fa = _pipeline(rows_a, hist_a, dim=dim)
    lb, pb, fb = _pipeline(rows_b, hist_b, dim=dim, group_size=16)
    ta = _int_table(rows_a, dim, 31)
    tb = _int_table(rows_b, dim, 32)
    fused = build_fused_image([la, lb], [ta, tb])
    assert fused.shape[0] == la.num_tiles + lb.num_tiles

    ev_a = zipf_queries(rows_a, 11, 6.0, seed=33)
    ev_b = zipf_queries(rows_b, 7, 4.0, seed=34)
    q_block = 4
    for S in (1, 2, 4):
        sp = plan_shards([la, lb], [pa, pb], S, group_freqs=[fa, fb])
        cq_a = offset_compiled_queries(
            compile_queries(la, ev_a, replica_block=q_block),
            sp.tables[0].tile_offset,
        )
        cq_b = offset_compiled_queries(
            compile_queries(lb, ev_b, replica_block=q_block),
            sp.tables[1].tile_offset,
        )
        fused_cq, spans = concat_compiled_queries([cq_a, cq_b], q_block)
        sbq = shard_block_queries(fused_cq, sp, q_block)
        images = jnp.asarray(sp.build_shard_images(fused))
        out_a, out_b = crossbar_reduce_tables(images, sbq, spans)
        np.testing.assert_array_equal(
            np.asarray(out_a),
            np.asarray(reduce_dense_oracle(jnp.asarray(ta), ev_a)),
        )
        np.testing.assert_array_equal(
            np.asarray(out_b),
            np.asarray(reduce_dense_oracle(jnp.asarray(tb), ev_b)),
        )


# ----------------------------------------------------- serving driver --


def test_sharded_server_serves_and_reports():
    from repro.serve import ShardedEmbeddingServer

    rows, dim = 128, 128
    rng = np.random.default_rng(40)
    tables = {
        "a": _int_table(rows, dim, 41),
        "b": _int_table(rows, dim, 42),
    }
    histories = {
        "a": zipf_queries(rows, 48, 5.0, seed=43),
        "b": zipf_queries(rows, 48, 5.0, seed=44),
    }
    server = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4,
        group_size=16, batch_size=8,
    )
    stream = zipf_queries(rows, 20, 5.0, seed=45)
    results = []
    for i, q in enumerate(stream):
        out = server.submit("a" if i % 2 == 0 else "b", q)
        if out:
            results.append(out)
    tail = server.flush()
    if tail:
        results.append(tail)
    assert server.stats.batches == len(results) >= 2
    assert server.stats.queries == 20
    # every served value matches the dense oracle on its logical table
    served = {"a": [], "b": []}
    for i, q in enumerate(stream):
        served["a" if i % 2 == 0 else "b"].append(q)
    got = {"a": [], "b": []}
    for r in results:
        for name, arr in r.items():
            got[name].append(np.asarray(arr))
    for name in ("a", "b"):
        want = np.asarray(
            reduce_dense_oracle(jnp.asarray(tables[name]), served[name])
        )
        np.testing.assert_array_equal(np.concatenate(got[name]), want)

    rep = server.report()
    assert rep["mode"] == "emulated"
    assert rep["serve"]["combine_bytes"] > 0
    assert rep["serve"]["max_grid_cells_per_flush"] > 0
    assert rep["plan"]["stored_tiles"] >= rep["plan"]["num_tiles"]


def test_combine_bytes_accounting():
    assert combine_bytes_per_batch(64, 128, 1) == 0
    b4 = combine_bytes_per_batch(64, 128, 4)
    # two ring passes of (S-1)/S * payload per shard, summed over shards
    assert b4 == int(2 * (3 / 4) * 64 * 128 * 4 * 4)
