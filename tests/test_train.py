"""Training substrate: optimizers, schedules, checkpoint/restart,
fault tolerance, gradient compression."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train.optimizer import (
    AdamW, Adafactor, clip_by_global_norm, cosine_schedule, wsd_schedule,
)
from repro.train import checkpoint as ckpt
from repro.train.compression import (
    compress, decompress, init_compression, compressed_bytes, raw_bytes,
)
from repro.train.fault_tolerance import (
    HeartbeatMonitor, StragglerDetector, plan_remesh, run_with_restarts,
)


# ------------------------------------------------------------ optimizer --

def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.array([0.5])}


def _loss(p):
    return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)


@pytest.mark.parametrize("opt_cls", [AdamW, Adafactor])
def test_optimizer_converges_on_quadratic(opt_cls):
    opt = opt_cls(schedule=lambda s: 0.1)
    params = _quadratic_params()
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(_loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(_loss(params)) < 1e-2


def test_adamw_weight_decay_shrinks_params():
    opt = AdamW(schedule=lambda s: 0.01, weight_decay=0.5)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    zero_grads = {"w": jnp.zeros((4,))}
    for _ in range(50):
        params, state = opt.update(zero_grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_adafactor_memory_is_factored():
    params = {"w": jnp.zeros((128, 64)), "e": jnp.zeros((1000, 32))}
    opt = Adafactor(schedule=lambda s: 1e-3)
    st = opt.init(params)
    full = sum(p.size for p in jax.tree.leaves(params))
    fact = sum(x.size for x in jax.tree.leaves((st.vr, st.vc)))
    assert fact < full / 10, "second moment must be factored"


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped = clip_by_global_norm(g, 1.0)
    norm = float(jnp.linalg.norm(clipped["a"]))
    assert abs(norm - 1.0) < 1e-5


def test_schedules_shapes():
    cos = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(cos(0)) == 0.0
    assert abs(float(cos(10)) - 1e-3) < 1e-9
    assert float(cos(100)) < 1e-5
    wsd = wsd_schedule(1e-3, warmup=10, stable=50, total=100)
    assert abs(float(wsd(30)) - 1e-3) < 1e-9  # plateau
    assert float(wsd(100)) < 1e-5


# ----------------------------------------------------------- checkpoint --

def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "step": jnp.array(7)},
    }
    d = str(tmp_path)
    ckpt.save(d, 5, tree)
    ckpt.save(d, 10, tree)
    # torn write: step 15 without COMMITTED must be ignored
    os.makedirs(os.path.join(d, "step_000000015"))
    assert ckpt.latest_step(d) == 10
    like = jax.eval_shape(lambda: tree)
    restored = ckpt.restore(d, 10, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_async(tmp_path):
    tree = {"w": jnp.ones((32, 32))}
    h = ckpt.save_async(str(tmp_path), 3, tree)
    h.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


# ------------------------------------------------------ fault tolerance --

def test_heartbeat_monitor_flags_dead_hosts():
    hb = HeartbeatMonitor(timeout_s=10)
    hb.beat(0, 1, t=100.0)
    hb.beat(1, 1, t=100.0)
    hb.beat(0, 2, t=115.0)
    assert hb.dead_hosts(now=116.0) == [1]
    assert hb.membership(now=116.0) == [0]


def test_straggler_detector():
    sd = StragglerDetector(window=8, threshold=2.0)
    for step in range(8):
        for h in range(4):
            sd.record(h, 1.0 if h != 2 else 3.5)
    assert sd.stragglers() == [2]


def test_plan_remesh_preserves_model_axis():
    assert plan_remesh(64, 4, model_parallelism=16) == (16, 16)
    assert plan_remesh(60, 4, model_parallelism=16) == (15, 16)   # lost hosts
    assert plan_remesh(64, 8, model_parallelism=16, pods=2) == (2, 16, 16)
    with pytest.raises(RuntimeError):
        plan_remesh(1, 4, model_parallelism=16)


def test_run_with_restarts_replays_to_same_result(tmp_path):
    """Injected crash mid-run; resumed run must match the uninterrupted one
    (deterministic data + checkpointed state)."""
    def make_runner(fail_at=None):
        calls = {"n": 0}
        store = {}

        def step_fn(step, state):
            if fail_at is not None and step == fail_at and calls["n"] == 0:
                calls["n"] += 1
                raise RuntimeError("injected node failure")
            return state + (step + 1)

        def save_fn(step, state):
            store["ckpt"] = (step, state)

        def restore_fn():
            return store.get("ckpt", (0, 0))

        return step_fn, save_fn, restore_fn

    s1, sv1, r1 = make_runner(fail_at=None)
    clean, _ = run_with_restarts(s1, 0, 25, save_fn=sv1, restore_fn=r1, save_every=10)
    s2, sv2, r2 = make_runner(fail_at=17)
    faulty, stats = run_with_restarts(s2, 0, 25, save_fn=sv2, restore_fn=r2, save_every=10)
    assert faulty == clean
    assert stats["restarts"] == 1
    assert stats["replayed_steps"] == 7  # 17 back to checkpoint at 10


# ----------------------------------------------------------- compression --

def test_compression_error_feedback_preserves_signal():
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    state = init_compression(grads)
    # accumulate many compressed steps of the SAME gradient; error feedback
    # must make the mean reconstruction converge to the true gradient
    acc = np.zeros(256, np.float32)
    n = 50
    for _ in range(n):
        payload, scales, state = compress(grads, state)
        acc += np.asarray(decompress(payload, scales)["w"])
    np.testing.assert_allclose(acc / n, np.asarray(grads["w"]), atol=2e-2)


def test_compression_wire_ratio():
    grads = {"w": jnp.ones((1024,), jnp.float32)}
    assert raw_bytes(grads) / compressed_bytes(grads) == 4.0
