"""Test bootstrap.

* Falls back to the deterministic hypothesis stub when the real
  `hypothesis` package is absent (this container does not ship it; the
  CI workflow installs the real one when available).
* Defaults ``RECROSS_VALIDATE=1`` so the structural validators
  (``repro.analysis.invariants``, DESIGN.md §12) run at plan build,
  patch apply-barriers and drain quiescence in every test; export
  ``RECROSS_VALIDATE=0`` to profile without them.
* Provides a stdlib per-test hang watchdog when `pytest-timeout` is
  absent: CI passes ``--timeout=600 --timeout-method=thread`` via
  ``PYTEST_ADDOPTS`` (a wedged driver thread or never-retiring flush
  must fail fast with a traceback, not hang the job for 45 minutes),
  and this fallback keeps the same protection — via
  ``faulthandler.dump_traceback_later(exit=True)`` — in environments
  where the plugin cannot be installed.  The budget comes from
  ``RECROSS_TEST_TIMEOUT_S`` (default 600; 0 disables).
"""

import faulthandler
import os
import sys

import pytest

os.environ.setdefault("RECROSS_VALIDATE", "1")

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

try:
    import pytest_timeout  # noqa: F401

    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False
    # the plugin is absent, so its --timeout/--timeout-method options
    # (e.g. from a CI-wide PYTEST_ADDOPTS) would make pytest error out
    # at startup — swallow them and let the faulthandler fallback honor
    # the same budget
    _TIMEOUT_S = float(os.environ.get("RECROSS_TEST_TIMEOUT_S", 600))

    def pytest_addoption(parser):
        parser.addoption("--timeout", type=float, default=None)
        parser.addoption("--timeout-method", default="thread")

    def pytest_configure(config):
        global _TIMEOUT_S
        opt = config.getoption("--timeout", default=None)
        if opt is not None:
            _TIMEOUT_S = float(opt)

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_protocol(item, nextitem):
        if _TIMEOUT_S > 0:
            # dumps every thread's traceback and kills the process if
            # the test (setup+call+teardown) exceeds the budget — the
            # closest stdlib analogue of pytest-timeout's thread method
            faulthandler.dump_traceback_later(_TIMEOUT_S, exit=True)
        try:
            yield
        finally:
            if _TIMEOUT_S > 0:
                faulthandler.cancel_dump_traceback_later()
