"""Test bootstrap: fall back to the deterministic hypothesis stub when the
real `hypothesis` package is absent (this container does not ship it; the
CI workflow installs the real one when available)."""

import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
