"""Minimal deterministic stand-in for `hypothesis` when it is not installed.

The real package is preferred (tests/conftest.py only installs this stub
on ImportError).  The stub keeps the property-test *shape*: ``@given``
re-runs the test over a deterministic sample sweep of each strategy
(bounds, midpoints, and seeded pseudorandom draws), so the properties are
still exercised across a spread of inputs — just without shrinking or
adaptive search.
"""

from __future__ import annotations

import functools
import itertools

import numpy as np

_DEFAULT_EXAMPLES = 8


class _Strategy:
    def samples(self, n: int):  # pragma: no cover - interface
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def samples(self, n: int):
        lo, hi = self.lo, self.hi
        base = [lo, hi, (lo + hi) // 2, min(lo + 1, hi), max(hi - 1, lo)]
        rng = np.random.default_rng(abs(hash((lo, hi))) % (2**32))
        while len(base) < n:
            base.append(int(rng.integers(lo, hi + 1)))
        return base[:n]


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def samples(self, n: int):
        import zlib

        base = list(self.elements)
        # crc32, not hash(): str hashing is PYTHONHASHSEED-randomized and
        # would break the stub's deterministic-sweep contract
        seed = zlib.crc32(repr(self.elements).encode())
        rng = np.random.default_rng(seed)
        while len(base) < n:
            base.append(self.elements[int(rng.integers(len(self.elements)))])
        return base[:n]


class strategies:  # noqa: N801 - mimics `hypothesis.strategies` module
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(elements) -> _SampledFrom:
        return _SampledFrom(elements)


def given(*strats: _Strategy):
    def deco(fn):
        n = getattr(fn, "_stub_max_examples", _DEFAULT_EXAMPLES)

        # no functools.wraps: pytest must see a zero-arg signature, not the
        # strategy parameters (it would try to resolve them as fixtures)
        def wrapper():
            cols = [s.samples(n) for s in strats]
            for combo in itertools.islice(zip(*cols), n):
                fn(*combo)

        wrapper.__name__ = fn.__name__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._stub_max_examples = min(max_examples, _DEFAULT_EXAMPLES)
        return fn

    return deco
