"""Plan-build-at-scale regressions (DESIGN.md §11).

Pins the three contracts the 10M-row plan build rests on:

* **blocked co-occurrence** — ``build_cooccurrence(block_pairs=...)``
  is bit-identical to the unblocked build for EVERY block size,
  including blocks smaller than a single bag's pair count (the chunker
  must still take whole patterns) and a single-chunk degenerate;
* **epoch-blocked grouping** — ``epoch=1`` is bit-identical to the
  retained scalar oracle; ``epoch>1`` covers every row exactly once,
  is deterministic, and holds the >= 99% intra-group co-occurrence
  mass bound on the template trace the scale bench runs;
* **blocked query compile** — ``compile_activations(block_queries=...)``
  is bit-identical across chunk sizes x replica blocking, chunk
  boundaries never splitting a round-robin unit;

plus the loud capacity guards on every packed-key encoding (pair keys,
grouping heap keys, wordline entry keys, producer gseqs) and the
scale-invariant ``compute_plan_patch`` candidates path against the
retained reference oracle.
"""

import numpy as np
import pytest

from repro.core import (
    build_cooccurrence,
    build_layout,
    compile_activations,
    correlation_aware_grouping,
    plan_replication,
    query_tile_bitmaps,
)
from repro.core.cooccurrence import (
    CoOccurrenceGraph,
    _check_pair_key_capacity,
)
from repro.core.grouping import (
    _reference_correlation_aware_grouping,
    frequency_grouping,
    grouping_quality,
)
from repro.core.mapping import _check_ent_key_capacity
from repro.data import scale_trace, zipf_queries
from repro.dist import compute_plan_patch, plan_shards
from repro.dist.replan import _reference_compute_plan_patch
from repro.serve.producers import ProducerRegistry

EQ1_BATCH = 64


def _graphs_equal(a: CoOccurrenceGraph, b: CoOccurrenceGraph) -> bool:
    return (
        a.num_rows == b.num_rows
        and a.num_queries == b.num_queries
        and np.array_equal(a.freq, b.freq)
        and np.array_equal(a.indptr, b.indptr)
        and np.array_equal(a.indices, b.indices)
        and np.array_equal(a.weights, b.weights)
    )


def _groupings_equal(a, b) -> bool:
    return (
        a.group_size == b.group_size
        and a.groups == b.groups
        and np.array_equal(a.group_of, b.group_of)
        and np.array_equal(a.slot_of, b.slot_of)
    )


def _acts_equal(a, b) -> bool:
    return (
        (a.batch, a.num_tiles, a.tile_rows) == (b.batch, b.num_tiles, b.tile_rows)
        and np.array_equal(a.act_qid, b.act_qid)
        and np.array_equal(a.act_tile, b.act_tile)
        and np.array_equal(a.act_rows, b.act_rows)
        and np.array_equal(a.ent_qid, b.ent_qid)
        and np.array_equal(a.ent_tile, b.ent_tile)
        and np.array_equal(a.ent_slot, b.ent_slot)
    )


def _patches_equal(a, b) -> bool:
    return (
        a.promoted == b.promoted
        and a.demoted == b.demoted
        and a.dma == b.dma
        and a.freed == b.freed
        and a.new_capacity == b.new_capacity
        and a.moved == b.moved
        and a.fetched == b.fetched
        and a.evicted == b.evicted
        and a.fetch_dma == b.fetch_dma
        and a.deferred == b.deferred
        and np.array_equal(a.drifted_load, b.drifted_load)
    )


# ------------------------------------------- blocked co-occurrence --


def test_blocked_cooc_bit_identity_across_block_sizes():
    qs = zipf_queries(500, 300, 8.0, seed=2)
    full = build_cooccurrence(qs, 500)
    for bp in (1, 7, 100, 4096, 10**9):
        assert _graphs_equal(build_cooccurrence(qs, 500, block_pairs=bp), full)


def test_blocked_cooc_block_smaller_than_one_bag():
    # one bag of 50 rows = 1225 pairs; block_pairs=1 must still take the
    # whole pattern per chunk (>= 1 pattern/chunk), never split a bag
    rng = np.random.default_rng(0)
    qs = [rng.choice(200, size=50, replace=False)] * 3 + [
        np.asarray(q) for q in zipf_queries(200, 40, 6.0, seed=1)
    ]
    full = build_cooccurrence(qs, 200)
    assert _graphs_equal(build_cooccurrence(qs, 200, block_pairs=1), full)


def test_blocked_cooc_degenerate_histories():
    # no pairs at all (all bags singleton) and an empty history: the
    # blocked path must agree without ever entering the chunk loop
    singles = [np.asarray([i % 10]) for i in range(20)]
    assert _graphs_equal(
        build_cooccurrence(singles, 10, block_pairs=4),
        build_cooccurrence(singles, 10),
    )
    assert _graphs_equal(
        build_cooccurrence([], 10, block_pairs=4),
        build_cooccurrence([], 10),
    )
    with pytest.raises(ValueError):
        build_cooccurrence(singles, 10, block_pairs=0)


def test_blocked_cooc_respects_max_pairs_cap():
    qs = zipf_queries(300, 120, 9.0, seed=5)
    full = build_cooccurrence(qs, 300, max_pairs_per_query=10)
    for bp in (1, 64, 10**8):
        assert _graphs_equal(
            build_cooccurrence(qs, 300, max_pairs_per_query=10, block_pairs=bp),
            full,
        )


# ---------------------------------------- epoch-blocked grouping ----


def test_epoch1_bit_identical_to_scalar_oracle():
    qs = zipf_queries(800, 600, 10.0, seed=4)
    g = build_cooccurrence(qs, 800)
    assert _groupings_equal(
        correlation_aware_grouping(g, 32),
        _reference_correlation_aware_grouping(g, 32),
    )


def test_epoch_grouping_covers_deterministically():
    qs = zipf_queries(4000, 3000, 10.0, seed=6)
    g = build_cooccurrence(qs, 4000)
    for ep in (4, 64):
        a = correlation_aware_grouping(g, 32, epoch=ep)
        # exactly-once cover
        seen = np.concatenate([np.asarray(grp) for grp in a.groups])
        assert seen.size == 4000 and np.array_equal(np.sort(seen),
                                                    np.arange(4000))
        # deterministic
        assert _groupings_equal(a, correlation_aware_grouping(g, 32, epoch=ep))
    with pytest.raises(ValueError):
        correlation_aware_grouping(g, 32, epoch=0)


def test_epoch_grouping_quality_floor_on_scale_trace():
    # the template-trace workload the scale bench runs: the hybrid must
    # keep >= 99% of the exact batch-heap's intra-group co-occurrence
    # mass (DESIGN.md §11 quality contract)
    qs = scale_trace(100_000, 20_000, 32.0, seed=3)
    g = build_cooccurrence(qs, 100_000, block_pairs=1 << 20)
    exact_q = grouping_quality(g, correlation_aware_grouping(g, 64))
    for ep in (16, 64):
        hyb = correlation_aware_grouping(g, 64, epoch=ep)
        assert grouping_quality(g, hyb) / max(exact_q, 1) >= 0.99


# ------------------------------------------ blocked query compile ----


def _small_layout(seed=0, rows=240, dim=32):
    qs = zipf_queries(rows, 160, 6.0, seed=seed)
    g = build_cooccurrence(qs, rows)
    grouping = correlation_aware_grouping(g, 16)
    plan = plan_replication(grouping, g.freq, EQ1_BATCH,
                            area_budget_ratio=1.5)
    return build_layout(grouping, plan, dim), qs


def test_blocked_compile_bit_identity():
    layout, qs = _small_layout()
    batch = [np.asarray(q) for q in qs[:40]]
    batch[7] = np.asarray([], dtype=np.int64)  # empty query mid-batch
    for rb in (1, 4):
        full = compile_activations(layout, batch, replica_block=rb)
        for bq in (1, 3, 64, 10**6):
            blk = compile_activations(layout, batch, replica_block=rb,
                                      block_queries=bq)
            assert _acts_equal(blk, full), (rb, bq)
    # dense bitmap oracle agrees with the blocked sparse compile
    bm, _counts = query_tile_bitmaps(layout, batch)
    blk = compile_activations(layout, batch, block_queries=5)
    scattered = np.zeros_like(bm)
    scattered[blk.ent_qid, blk.ent_tile, blk.ent_slot] = 1
    assert np.array_equal(scattered, bm)


def test_blocked_compile_round_robin_spans_chunks():
    # replicated groups must round-robin ACROSS chunk boundaries: with
    # balancing on, per-tile assignment counts must match the unblocked
    # compile even when every chunk holds a single round-robin unit
    layout, qs = _small_layout(seed=3)
    batch = [np.asarray(q) for q in qs[:60]]
    full = compile_activations(layout, batch, balance_replicas=True)
    blk = compile_activations(layout, batch, balance_replicas=True,
                              block_queries=1)
    assert _acts_equal(blk, full)
    off = compile_activations(layout, batch, balance_replicas=False,
                              block_queries=2)
    assert _acts_equal(
        off, compile_activations(layout, batch, balance_replicas=False)
    )


def test_blocked_compile_all_empty_batch():
    layout, _ = _small_layout(seed=1)
    batch = [np.asarray([], dtype=np.int64)] * 4
    assert _acts_equal(
        compile_activations(layout, batch, block_queries=2),
        compile_activations(layout, batch),
    )


# ----------------------------------------------- capacity guards ----


def test_pair_key_capacity_guard():
    _check_pair_key_capacity(3_037_000_499)  # boundary fits
    with pytest.raises(NotImplementedError):
        _check_pair_key_capacity(3_037_000_500)
    # checked up front in build_cooccurrence — before any O(rows) alloc
    with pytest.raises(NotImplementedError):
        build_cooccurrence([np.asarray([0, 1])], 4_000_000_000)


def test_grouping_heap_key_capacity_guard():
    # total edge mass << shift must not bleed into the id bits
    w = np.asarray([1 << 61, 1 << 61], dtype=np.int64)
    g = CoOccurrenceGraph(
        num_rows=4,
        freq=np.asarray([2, 2, 0, 0], dtype=np.int64),
        indptr=np.asarray([0, 1, 2, 2, 2], dtype=np.int64),
        indices=np.asarray([1, 0], dtype=np.int64),
        weights=w,
        num_queries=2,
    )
    with pytest.raises(ValueError, match="heap keys overflow"):
        correlation_aware_grouping(g, 2)


def test_ent_key_capacity_guard():
    layout, _ = _small_layout(seed=2)
    _check_ent_key_capacity(layout, 1024)  # sane batch fits
    huge = (1 << 63) // (layout.num_tiles * layout.tile_rows) + 1
    with pytest.raises(ValueError, match="block_queries"):
        _check_ent_key_capacity(layout, huge)


def test_producer_gseq_capacity_guard():
    reg = ProducerRegistry(stride=1 << 40)
    assert reg.stamp("p", "t") == 0  # normal stamp fine
    pid = reg.pid("p")
    reg._next[pid]["t"] = 1 << 23  # (local+1) * 2^40 > 2^63 - 1
    with pytest.raises(OverflowError, match="sequence capacity"):
        reg.stamp("p", "t")


# ------------------------------ scale-invariant plan patch math ------


def _patch_setup(seed, num_rows=3000, S=3):
    rng = np.random.default_rng(seed)
    ranks = rng.permutation(num_rows).astype(np.float64) + 1.0
    freq = (1e6 / ranks ** 1.05).astype(np.int64) + 1
    g = CoOccurrenceGraph(
        num_rows=num_rows, freq=freq,
        indptr=np.zeros(num_rows + 1, dtype=np.int64),
        indices=np.empty(0, dtype=np.int64),
        weights=np.empty(0, dtype=np.int64),
        num_queries=num_rows // 10,
    )
    grouping = frequency_grouping(g, 16)
    plan = plan_replication(grouping, g.freq, EQ1_BATCH)
    layout = build_layout(grouping, plan, 8)
    gfreq = grouping.group_freq(g.freq)
    sp = plan_shards([layout], [plan], S, group_freqs=[gfreq],
                     eq1_batch=EQ1_BATCH)
    return sp, gfreq


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_patch_matches_reference_oracle(seed):
    sp, gfreq = _patch_setup(seed)
    rng = np.random.default_rng(seed + 100)
    repl = np.flatnonzero(sp.replicated_group)
    cold = np.argsort(gfreq, kind="stable")[:12]
    hot = repl[: min(12, repl.size)]
    drift = gfreq.astype(np.float64)
    drift[hot] *= 0.02
    drift[cold] += float(gfreq[hot].sum()) * 0.98 / max(cold.size, 1)
    for kw in ({}, {"shrink_slack": 1},
               {"capacity": int(sp.max_local_tiles) + 4}):
        ref = _reference_compute_plan_patch(sp, drift, eq1_batch=EQ1_BATCH,
                                            **kw)
        new = compute_plan_patch(sp, drift, eq1_batch=EQ1_BATCH, **kw)
        assert _patches_equal(new, ref), kw
        # mass-preserving drift: the candidates path is EXACT, not a
        # heuristic (DESIGN.md §11)
        cand = compute_plan_patch(sp, drift, eq1_batch=EQ1_BATCH,
                                  candidates=np.union1d(cold, hot), **kw)
        assert _patches_equal(cand, ref), kw


def test_patch_noop_with_empty_candidates():
    sp, gfreq = _patch_setup(7)
    p = compute_plan_patch(sp, gfreq.astype(np.float64),
                           eq1_batch=EQ1_BATCH,
                           candidates=np.empty(0, dtype=np.int64))
    assert not p.promoted and not p.demoted and not p.dma and not p.freed
    assert np.array_equal(p.drifted_load, gfreq.astype(np.float64))
