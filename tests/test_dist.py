"""Sharding rules: spec derivation, sanitization, logical-axis plumbing.

These run on the single local device: we validate SPECS (pure metadata),
not placements — the 512-device placement is covered by the dry-run.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.sharding import (
    LOGICAL_RULES_SINGLE_POD,
    LOGICAL_RULES_MULTI_POD,
    activation_sharding_ctx,
    logical_to_spec,
    maybe_shard,
    maybe_shard_any,
    param_specs_for,
    sanitize_spec,
)


class _FakeMesh:
    """Carries axis names/sizes for spec logic without 256 devices."""

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))


MESH = _FakeMesh({"data": 16, "model": 16})


def test_logical_to_spec_basic():
    spec = logical_to_spec(("batch", "seq", "mlp"), LOGICAL_RULES_SINGLE_POD)
    assert spec == P("data", None, "model")
    spec = logical_to_spec(("batch", None), LOGICAL_RULES_MULTI_POD)
    assert spec == P(("pod", "data"), None)


def test_sanitize_spec_drops_nondivisible():
    assert sanitize_spec(P("model", None), (122753, 64), MESH) == P(None, None)
    assert sanitize_spec(P("model", None), (122880, 64), MESH) == P("model", None)
    assert sanitize_spec(P(("pod", "data"), None), (48, 8),
                         _FakeMesh({"pod": 2, "data": 16, "model": 16})) == P(None, None)


def test_sanitize_spec_tuple_axis_multi_pod_regression():
    """Tuple specs on the multi-pod mesh: a non-divisible dim falls back to
    replicated WITHOUT shortening the spec (positional alignment), and a
    divisible dim keeps the whole tuple."""
    multi = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    # 48 % (2*16) != 0 → replicated, spec length preserved
    assert sanitize_spec(P(("pod", "data")), (48,), multi) == P(None)
    assert len(sanitize_spec(P(("pod", "data")), (48,), multi)) == 1
    assert sanitize_spec(P(("pod", "data"), "model"), (48, 31), multi) == P(None, None)
    # 64 % 32 == 0 → the tuple survives intact
    assert sanitize_spec(P(("pod", "data")), (64,), multi) == P(("pod", "data"))
    assert sanitize_spec(P(("pod", "data"), "model"), (64, 32), multi) == \
        P(("pod", "data"), "model")


def test_sanitize_spec_drops_unknown_mesh_axes():
    """An axis the mesh does not carry must sanitize away even when the
    dim is divisible — treating it as size 1 would hand an invalid spec
    to with_sharding_constraint (e.g. "pod" on the single-pod mesh)."""
    assert sanitize_spec(P(("pod", "data")), (64,), MESH) == P(None)
    assert sanitize_spec(P("pod", None), (48, 8), MESH) == P(None, None)
    # known axes in the same spec survive
    assert sanitize_spec(P("pod", "model"), (48, 32), MESH) == P(None, "model")


def test_param_specs_attention_and_mlp():
    params = {
        "layers": {
            "attn": {"wq": jnp.zeros((4, 64, 128)), "wo": jnp.zeros((4, 128, 64))},
            "mlp": {"in_gate": jnp.zeros((4, 64, 256)), "out": jnp.zeros((4, 256, 64))},
            "norm_attn": {"scale": jnp.zeros((4, 64))},
        },
        "embed": jnp.zeros((1024, 64)),
        "lm_head": jnp.zeros((64, 1024)),
    }
    specs = param_specs_for(params, LOGICAL_RULES_SINGLE_POD)
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", "data")
    assert specs["layers"]["mlp"]["in_gate"] == P(None, "data", "model")
    assert specs["layers"]["mlp"]["out"] == P(None, "model", "data")
    assert specs["layers"]["norm_attn"]["scale"] == P()
    assert specs["embed"] == P("model", "data")
    assert specs["lm_head"] == P("data", "model")


def test_param_specs_moe_expert_layout():
    params = {
        "moe": {
            "w_gate": jnp.zeros((8, 64, 256)),
            "w_val": jnp.zeros((8, 64, 256)),
            "w_out": jnp.zeros((8, 256, 64)),
            "router": jnp.zeros((64, 8)),
        }
    }
    specs = param_specs_for(params, LOGICAL_RULES_SINGLE_POD, moe=True)
    # experts logical axis maps to None (neither assigned arch divides TP);
    # fsdp/mlp carry the sharding
    assert specs["moe"]["w_gate"] == P(None, "data", "model")
    assert specs["moe"]["w_out"] == P(None, "model", "data")
    assert specs["moe"]["router"] in (P(), P(None, None))


def test_param_specs_no_gate_collision():
    """'in_gate' must NOT match the scalar 'gate' replicate pattern."""
    params = {"mlp": {"in_gate": jnp.zeros((64, 256))},
              "xattn": {"gate": jnp.zeros((1,))}}
    specs = param_specs_for(params, LOGICAL_RULES_SINGLE_POD)
    assert specs["mlp"]["in_gate"] == P("data", "model")
    assert specs["xattn"]["gate"] == P()


def test_maybe_shard_noop_outside_context():
    x = jnp.ones((4, 4))
    y = maybe_shard(x, ("batch", None))
    assert y is x  # identity without installed rules


def test_maybe_shard_applies_constraint_on_real_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with activation_sharding_ctx(mesh, LOGICAL_RULES_SINGLE_POD):
        def f(x):
            return maybe_shard(x, ("batch", "mlp")) * 2
        out = jax.jit(f)(jnp.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4, 4)))


def test_maybe_shard_any_fallback_order():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with activation_sharding_ctx(mesh, LOGICAL_RULES_SINGLE_POD):
        x = jnp.ones((3, 5))  # nothing divides cleanly except 1-sized axes
        y = maybe_shard_any(x, [("batch", "mlp"), (None, None)])
        assert y.shape == x.shape


def test_maybe_shard_any_prefers_first_surviving(monkeypatch):
    """The FIRST candidate whose spec fully survives sanitization must be
    the one applied — later candidates are never considered."""
    import repro.dist.sharding as sh

    applied = []

    def record_constraint(x, sharding):
        applied.append(sharding.spec)
        return x

    monkeypatch.setattr(jax.lax, "with_sharding_constraint", record_constraint)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = dict(LOGICAL_RULES_SINGLE_POD)
    with activation_sharding_ctx(mesh, rules):
        x = jnp.ones((4, 4))
        # both candidates survive on the 1x1 mesh → first wins
        sh.maybe_shard_any(x, [("batch", "mlp"), (None, None)])
        assert applied[-1] == P("data", "model")
        # first candidate names an axis this mesh lacks → falls through
        # to the next fully-surviving candidate
        multi_rules = dict(rules, batch=("pod", "data"))
        with activation_sharding_ctx(mesh, multi_rules):
            sh.maybe_shard_any(x, [("batch", None), (None, "mlp")])
            assert applied[-1] == P(None, "model")
    assert len(applied) == 2
