"""Numerical equivalence of every embedding-reduction datapath, plus
hypothesis property tests over random layouts/queries."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import baselines, build_cooccurrence, compile_queries
from repro.core.reduction import reduce_dense_oracle, reduce_via_layout
from repro.data import zipf_queries
from repro.kernels import crossbar_reduce


def _setup(rows, dim, n_hist, n_eval, seed, group_size=16):
    qs = zipf_queries(rows, n_hist + n_eval, 8.0, seed=seed)
    graph = build_cooccurrence(qs[:n_hist], rows)
    layout, _ = baselines.recross_pipeline(
        graph, qs[n_hist:], group_size=group_size, dim=dim
    )
    table = np.random.default_rng(seed).normal(size=(rows, dim)).astype(np.float32)
    return layout, table, qs[n_hist:]


@given(st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_layout_reduction_equals_oracle(seed):
    """Property: for ANY layout built from ANY trace, reduction through the
    physical image equals gather+sum on the logical table."""
    rows, dim = 256, 128
    layout, table, ev = _setup(rows, dim, 32, 16, seed)
    cq = compile_queries(layout, ev)
    image = jnp.asarray(layout.build_image(table))
    out = reduce_via_layout(image, cq.tile_ids, cq.bitmaps, tile_rows=layout.tile_rows)
    ref = reduce_dense_oracle(jnp.asarray(table), ev)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@given(st.integers(0, 20))
@settings(max_examples=6, deadline=None)
def test_kernel_equals_oracle_random_layouts(seed):
    rows, dim = 200, 128
    layout, table, ev = _setup(rows, dim, 24, 8, seed)
    cq = compile_queries(layout, ev)
    image = jnp.asarray(
        layout.build_image(table).reshape(layout.num_tiles, layout.tile_rows, dim)
    )
    out = crossbar_reduce(image, cq.tile_ids, cq.bitmaps)
    ref = reduce_dense_oracle(jnp.asarray(table), ev)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_dynamic_switch_does_not_change_values():
    layout, table, ev = _setup(256, 128, 32, 16, 7)
    cq = compile_queries(layout, ev)
    image = jnp.asarray(layout.build_image(table))
    a = reduce_via_layout(image, cq.tile_ids, cq.bitmaps,
                          tile_rows=layout.tile_rows, dynamic_switch=True)
    b = reduce_via_layout(image, cq.tile_ids, cq.bitmaps,
                          tile_rows=layout.tile_rows, dynamic_switch=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_replicas_hold_identical_values():
    """All replica tiles of a group serve the same numerics (any replica
    choice gives the same reduction)."""
    layout, table, ev = _setup(128, 128, 64, 8, 11)
    image = jnp.asarray(layout.build_image(table))
    cq_bal = compile_queries(layout, ev, balance_replicas=True)
    cq_first = compile_queries(layout, ev, balance_replicas=False)
    a = reduce_via_layout(image, cq_bal.tile_ids, cq_bal.bitmaps,
                          tile_rows=layout.tile_rows)
    b = reduce_via_layout(image, cq_first.tile_ids, cq_first.bitmaps,
                          tile_rows=layout.tile_rows)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_empty_and_single_row_queries():
    layout, table, _ = _setup(64, 128, 16, 4, 13)
    ev = [np.array([0]), np.array([5, 5]), np.array([63])]
    cq = compile_queries(layout, ev)
    image = jnp.asarray(layout.build_image(table))
    out = reduce_via_layout(image, cq.tile_ids, cq.bitmaps, tile_rows=layout.tile_rows)
    ref = reduce_dense_oracle(jnp.asarray(table), ev)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
