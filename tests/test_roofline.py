"""Roofline machinery: HLO collective parsing (incl. loop-trip correction)
and analytic cost sanity."""

import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch.analytic import cell_cost, forward_flops
from repro.launch.roofline import (
    RooflineReport,
    _shape_bytes,
    collective_bytes_from_hlo,
)


def test_shape_bytes():
    assert _shape_bytes("bf16[256,4096]{1,0}") == 256 * 4096 * 2
    assert _shape_bytes("f32[8]") == 32
    assert _shape_bytes("(bf16[4,4]{1,0}, f32[2])") == 32 + 8
    assert _shape_bytes("pred[]") == 1


FLAT_HLO = """
HloModule jit_f

ENTRY %main.1 (a: bf16[128,64]) -> bf16[128,64] {
  %a = bf16[128,64]{1,0} parameter(0)
  %ar = bf16[128,64]{1,0} all-reduce(%a), replica_groups={}, to_apply=%add
  ROOT %r = bf16[128,64]{1,0} copy(%ar)
}
"""


def test_collective_bytes_flat():
    out = collective_bytes_from_hlo(FLAT_HLO)
    assert out == {"all-reduce": 128 * 64 * 2}


LOOPED_HLO = """
HloModule jit_f

%region_body.1 (t: (s32[], bf16[64,64])) -> (s32[], bf16[64,64]) {
  %t = (s32[], bf16[64,64]{1,0}) parameter(0)
  %g = bf16[64,64]{1,0} all-gather(%x), dimensions={0}
  ROOT %out = (s32[], bf16[64,64]{1,0}) tuple(%i, %g)
}

%region_cond.2 (t2: (s32[], bf16[64,64])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main.3 (a: bf16[64,64]) -> bf16[64,64] {
  %a = bf16[64,64]{1,0} parameter(0)
  %w = (s32[], bf16[64,64]{1,0}) while(%init), condition=%region_cond.2, body=%region_body.1, backend_config={"known_trip_count":{"n":"7"}}
  %ar = bf16[64,64]{1,0} all-reduce(%gte), to_apply=%add
  ROOT %r = bf16[64,64]{1,0} copy(%ar)
}
"""


def test_collective_bytes_loop_corrected():
    out = collective_bytes_from_hlo(LOOPED_HLO)
    assert out["all-gather"] == 7 * 64 * 64 * 2, "while-body collective must be x7"
    assert out["all-reduce"] == 64 * 64 * 2


def test_collective_parser_on_real_lowering():
    """End-to-end: a psum inside lax.scan is multiplied by the trip count."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    if jax.device_count() < 2:
        mesh = jax.make_mesh((1,), ("i",))
    else:
        mesh = jax.make_mesh((jax.device_count(),), ("i",))

    def f(x):
        def body(c, _):
            return jax.lax.with_sharding_constraint(
                jnp.tanh(c), NamedSharding(mesh, P(None, "i"))
            ), None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out.sum()

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, jax.device_count()), jnp.float32)
    )
    compiled = lowered.compile()
    # parser must not crash on a real optimized module
    out = collective_bytes_from_hlo(compiled.as_text())
    assert isinstance(out, dict)


def test_roofline_report_terms():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="pod16x16", chips=256,
        hlo_flops=1e15, hlo_bytes=1e12, collective_bytes=1e12,
        collective_breakdown={}, analytic_flops=5.04e16, analytic_bytes=2e13,
    )
    # compute = 5.04e16/(256*197e12) ~ 1e-3 s — dominates the other terms
    assert abs(rep.compute_s - 5.04e16 / (256 * 197e12)) < 1e-9
    assert rep.memory_s == pytest.approx(2e13 / (256 * 819e9))
    assert rep.collective_s == pytest.approx(1e12 / (256 * 50e9))
    assert rep.dominant == "compute"
    assert rep.roofline_fraction == pytest.approx(1.0)


@pytest.mark.parametrize("arch", ["minicpm-2b", "grok-1-314b", "zamba2-7b"])
def test_analytic_flops_close_to_6nd(arch):
    """Analytic forward FLOPs must land within 2.5x of 2·N_active·tokens
    (they include attention/routing overheads that 6ND ignores)."""
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    fwd = forward_flops(cfg, shape.global_batch, shape.seq_len)
    six_nd = 2.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    assert 0.7 <= fwd / six_nd <= 2.5, fwd / six_nd


def test_cell_cost_kinds():
    cfg = get_config("minicpm-2b")
    tr = cell_cost(cfg, SHAPES["train_4k"])
    pf = cell_cost(cfg, SHAPES["prefill_32k"])
    dc = cell_cost(cfg, SHAPES["decode_32k"])
    assert tr.flops > pf.flops > dc.flops
    assert dc.hbm_bytes > 0
