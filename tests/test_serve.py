"""Serving stack: KV caches, continuous batching, long-context decode."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import forward, init_lm
from repro.serve.batching import Request, RequestBatcher
from repro.serve.decode import decode_step
from repro.serve.kvcache import cache_bytes, init_cache


def test_cache_shapes_and_bytes():
    cfg = get_config("chatglm3-6b", smoke=True)
    cache = init_cache(cfg, batch=2, max_seq=32)
    assert cache["k"].shape == (cfg.num_layers, 2, 32, cfg.kv_heads,
                                cfg.resolved_head_dim)
    assert cache_bytes(cache) > 0


def test_ring_cache_window_bounded():
    cfg = get_config("zamba2-7b", smoke=True)
    cache = init_cache(cfg, batch=1, max_seq=1 << 19, window=16)
    # hybrid cache memory must NOT scale with max_seq (ring window + states)
    assert cache["shared"]["k"].shape[2] == 16
    assert cache_bytes(cache) < 50e6


def test_zamba_ring_decode_beyond_window():
    """Decode past the ring window: old entries are overwritten and the
    model keeps producing finite logits (sliding-window semantics)."""
    cfg = get_config("zamba2-7b", smoke=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    W = 8
    cache = init_cache(cfg, batch=1, max_seq=1 << 12, window=W)
    tok = jnp.zeros((1, 1), jnp.int32)
    step = jax.jit(lambda c, t: decode_step(params, cfg, t, c))
    for t in range(2 * W + 3):
        logits, cache = step(cache, (tok + t) % cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"NaN at step {t}"
    assert int(cache["len"]) == 2 * W + 3


def test_decode_window_equals_full_within_window():
    """While total length <= window, ring decode == unbounded decode."""
    cfg = get_config("zamba2-7b", smoke=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab_size)
    c_big = init_cache(cfg, 1, 64, window=64)
    c_small = init_cache(cfg, 1, 64, window=8)
    outs = []
    for c in (c_big, c_small):
        got = []
        cc = c
        for t in range(6):
            lg, cc = decode_step(params, cfg, toks[:, t:t + 1], cc)
            got.append(np.asarray(lg))
        outs.append(np.concatenate(got, axis=1))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)


def test_request_batcher_drains_and_measures():
    batcher = RequestBatcher(batch_size=2, eos_id=-1)
    for uid in range(5):
        batcher.submit(Request(uid=uid, prompt=np.array([1, 2]), max_new_tokens=4))

    def prefill_fn(slot, prompt):
        return int(prompt[-1]) + 1

    def decode_fn(active, last):
        return last + 1

    ticks = 0
    while not batcher.idle:
        batcher.tick(prefill_fn, decode_fn)
        ticks += 1
        assert ticks < 100
    s = batcher.metrics.summary()
    assert s["completed"] == 5
    assert s["tokens_out"] > 0


def test_request_batcher_respects_slot_limit():
    batcher = RequestBatcher(batch_size=2, eos_id=-1)
    for uid in range(4):
        batcher.submit(Request(uid=uid, prompt=np.array([1]), max_new_tokens=100))
    active = batcher.tick(lambda s, p: 0, lambda a, l: l)
    assert active == 2  # only two slots admitted
