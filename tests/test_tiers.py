"""Tiered host↔device embedding storage (DESIGN.md §9).

The contract: a capacity-bounded server is BIT-IDENTICAL to the
uncapped all-resident oracle — the hot tier changes *where* a reduction
computes (crossbar kernels vs host gather+sum), never *what* it
computes.  Bit-identity is pinned on integer-valued float tables (every
partial sum exact in f32), so the tests reject a wrong, dropped or
double-counted activation at the tier boundary — the failure modes of
a broken residency split or paging patch.

Also pinned here: the capacity-bounded planner's budget/admission
invariants, hysteresis anti-thrash, the paging patch's free-list
bookkeeping through ``patch_shard_images`` edge cases (zero-moved-tile
and evict-only patches, fetch failure under fault injection), the
scheduler's cold-query guard, the drift-observation memo and the
bounded jit-dispatch caches.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    build_cooccurrence,
    build_layout,
    compile_queries,
    correlation_aware_grouping,
    plan_replication,
    shard_block_queries,
)
from repro.core.reduction import reduce_dense_oracle
from repro.data import zipf_queries
from repro.dist import (
    COLD,
    PagingPolicy,
    apply_plan_patch,
    build_fused_image,
    compute_plan_patch,
    plan_shards,
)
from repro.dist.replan import PlanPatch
from repro.kernels import crossbar_reduce_sharded, patch_shard_images
from repro.kernels.sharded import (
    DISPATCH_CACHE_MAXSIZE,
    clear_dispatch_caches,
    dispatch_cache_stats,
)
from repro.serve import (
    FlushPolicy,
    FlushScheduler,
    LoadObservationCache,
    ReplanConfig,
    RetryPolicy,
    ShardedEmbeddingServer,
    TierConfig,
)
from repro.serve.faults import FaultPlan
from repro.serve.tiers import HostFetchQueue, ResidencyIndex

EQ1_BATCH = 64


def _int_table(rows, dim, seed):
    """Integer-valued f32 table: partial sums are exact in float32."""
    return np.random.default_rng(seed).integers(
        -8, 9, size=(rows, dim)
    ).astype(np.float32)


def _pipeline(rows, hist, *, group_size=16, dim=128):
    g = build_cooccurrence(hist, rows)
    grouping = correlation_aware_grouping(g, group_size)
    plan = plan_replication(grouping, g.freq, EQ1_BATCH)
    layout = build_layout(grouping, plan, dim)
    return layout, plan, grouping.group_freq(g.freq)


def _capped_setup(seed, *, rows=192, dim=128, S=2, cap_frac=0.5):
    hist = zipf_queries(rows, 48, 6.0, seed=seed)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, seed)
    fused = build_fused_image([layout], [table])
    uncapped = plan_shards([layout], [plan], S, group_freqs=[gfreq])
    cap = max(1, int(uncapped.max_local_tiles * cap_frac))
    sp = plan_shards([layout], [plan], S, group_freqs=[gfreq],
                     capacity_tiles=cap)
    return layout, table, fused, uncapped, sp, cap


def _servers(seed, tiers, **kw):
    """(oracle, capped) server pair over the same tables/stream knobs."""
    rows, dim = kw.pop("rows", 320), kw.pop("dim", 128)
    rng = np.random.default_rng(seed)
    tables = {"a": _int_table(rows, dim, seed),
              "b": _int_table(rows, dim, seed + 1)}
    histories = {n: zipf_queries(rows, 64, 5.0, seed=seed + i)
                 for i, n in enumerate(tables)}
    mk = lambda t: ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=16, tiers=t, **kw,
    )
    return mk(None), mk(tiers), tables, rng


# --------------------------------------------- capacity-bounded plan --


def test_capacity_plan_respects_budget_and_keeps_hottest():
    layout, _table, _fused, uncapped, sp, cap = _capped_setup(3)
    assert sp.capacity_tiles == cap
    # budget respected on every shard
    assert int(sp.local_num_tiles.max()) <= cap
    assert sp.max_local_tiles <= cap
    # something had to go cold at half capacity
    assert sp.cold_tiles > 0 and sp.cold_groups.size > 0
    # cold groups' tiles are held by NO shard; resident tiles behave
    # exactly as before (owned once or replicated everywhere)
    for t in range(sp.num_tiles):
        holders = int((sp.local_tile_of[:, t] >= 0).sum())
        if sp.shard_of_tile[t] == COLD:
            assert holders == 0, (t, holders)
        elif sp.shard_of_tile[t] == -1:
            assert holders == sp.num_shards
        else:
            assert holders == 1
    # greedy admission is hottest-first: every cold group's load is <=
    # the minimum load over resident SHARDED groups of the same table
    # (replicated groups may degrade to sharded, so compare like kinds)
    res_sharded = (sp.shard_of_group >= 0) & ~sp.replicated_group
    if res_sharded.any():
        assert sp.group_load[sp.cold_groups].max() <= (
            sp.group_load[res_sharded].max()
        )
    summary = sp.memory_summary()
    assert summary["cold_tiles"] == sp.cold_tiles
    assert summary["capacity_tiles"] == cap
    assert 0.0 < summary["resident_tile_fraction"] < 1.0


def test_huge_capacity_matches_uncapped_plan():
    # a budget the working set never touches must not change placement
    hist = zipf_queries(192, 48, 6.0, seed=5)
    layout2, plan2, gfreq2 = _pipeline(192, hist)
    a = plan_shards([layout2], [plan2], 2, group_freqs=[gfreq2])
    b = plan_shards([layout2], [plan2], 2, group_freqs=[gfreq2],
                    capacity_tiles=10_000)
    np.testing.assert_array_equal(a.shard_of_group, b.shard_of_group)
    np.testing.assert_array_equal(a.replicated_group, b.replicated_group)
    np.testing.assert_array_equal(a.local_tile_of, b.local_tile_of)
    assert b.cold_tiles == 0


def test_tier_config_validation():
    with pytest.raises(ValueError):
        TierConfig()                                  # neither knob
    with pytest.raises(ValueError):
        TierConfig(capacity_tiles=8, capacity_frac=0.5)   # both
    with pytest.raises(ValueError):
        TierConfig(capacity_frac=1.5)
    with pytest.raises(ValueError):
        TierConfig(capacity_tiles=8, hysteresis=0.9)
    tc = TierConfig(capacity_frac=0.25)
    assert tc.resolve_capacity(40) == 10
    assert tc.resolve_capacity(2) == 1                # floor >= 1
    assert TierConfig(capacity_tiles=7).resolve_capacity(40) == 7
    pol = tc.paging_policy(10)
    assert isinstance(pol, PagingPolicy) and pol.capacity_tiles == 10


# ------------------------------------------- capped ≡ oracle serving --


@pytest.mark.parametrize("policy,threaded", [
    ("global", False), ("deadline", False), ("owner-set", True),
])
def test_capped_server_bit_identical_to_uncapped_oracle(policy, threaded):
    oracle, capped, tables, rng = _servers(
        11, TierConfig(capacity_frac=0.5),
        flush_policy=policy, threaded=threaded,
    )
    assert capped.plan.cold_groups.size > 0, "cap did not bite; resize test"
    rows = tables["a"].shape[0]
    stream = [("a" if i % 2 else "b",
               rng.integers(0, rows, size=rng.integers(1, 6)).tolist())
              for i in range(180)]
    if policy == "global":
        by = {"a": [q for n, q in stream if n == "a"],
              "b": [q for n, q in stream if n == "b"]}
        got = capped.serve(by)
        want = oracle.serve(by)
        for n in by:
            np.testing.assert_array_equal(
                np.asarray(got[n]), np.asarray(want[n]))
    else:
        for n, q in stream:
            capped.submit(n, q)
            oracle.submit(n, q)
        got, want = capped.drain(), oracle.drain()
        capped.close(), oracle.close()
        assert set(got) == set(want)
        for n in got:
            np.testing.assert_array_equal(
                np.asarray(got[n]), np.asarray(want[n]))
    ts = capped.stats.tier_summary()
    assert ts["host_queries"] > 0, "cap never exercised the host path"
    assert ts["hot_queries"] + ts["host_queries"] == len(stream)
    assert oracle.stats.host_queries == 0


def test_paging_replay_fetches_evicts_and_stays_exact():
    """Skewed traffic onto initially-cold groups must page them in
    (fetch), displace colder residents (evict), and keep every drained
    row bit-identical to the uncapped oracle throughout."""
    oracle, capped, tables, rng = _servers(
        7, TierConfig(capacity_frac=0.5, hysteresis=1.1),
        flush_policy="deadline",
        replan=ReplanConfig(threshold=0.2, half_life=4, min_queries=32),
    )
    cold = capped.plan.cold_groups
    assert cold.size > 0
    gof = capped._residency._fused_group_of_row["a"]
    cold_rows = np.nonzero(np.isin(gof, cold))[0]
    assert cold_rows.size > 0
    rows = tables["a"].shape[0]
    got_chunks, want_chunks = [], []
    for i in range(480):
        if i % 3:
            q = rng.choice(cold_rows[:40], size=rng.integers(1, 5)).tolist()
        else:
            q = rng.integers(0, rows, size=rng.integers(1, 5)).tolist()
        capped.submit("a", q)
        oracle.submit("a", q)
        if (i + 1) % 96 == 0:
            g, w = capped.drain(), oracle.drain()
            got_chunks.append(np.asarray(g["a"]))
            want_chunks.append(np.asarray(w["a"]))
    g, w = capped.drain(), oracle.drain()
    if "a" in g:
        got_chunks.append(np.asarray(g["a"]))
        want_chunks.append(np.asarray(w["a"]))
    got = np.concatenate(got_chunks)
    want = np.concatenate(want_chunks)
    np.testing.assert_array_equal(got, want)
    ts = capped.stats.tier_summary()
    assert ts["fetched_tiles"] > 0, ts
    assert ts["evicted_tiles"] > 0, ts
    assert ts["paging_bytes"] == ts["fetched_tiles"] * capped._tile_bytes
    # budget held through every patch
    assert int(capped.plan.local_num_tiles.max()) <= capped._capacity_tiles
    assert int(capped.shard_images.shape[1]) == capped._capacity_tiles
    rep = capped.report()
    assert rep["tiers"]["capacity_tiles"] == capped._capacity_tiles
    assert rep["serve"]["tiers"]["fetched_tiles"] == ts["fetched_tiles"]


# ----------------------------------------------- hysteresis anti-thrash --


def _paging_scenario(seed=3):
    """A capped single-shard plan with zero free slots, plus maps."""
    rows = 192
    hist = zipf_queries(rows, 48, 6.0, seed=seed)
    layout, plan, gfreq = _pipeline(rows, hist)
    uncapped = plan_shards([layout], [plan], 1, group_freqs=[gfreq])
    cap = max(2, uncapped.max_local_tiles // 2)
    sp = plan_shards([layout], [plan], 1, group_freqs=[gfreq],
                     capacity_tiles=cap)
    # shave capacity down to exactly the occupied slot count so a fetch
    # MUST evict (no free slots to absorb it)
    sp = plan_shards([layout], [plan], 1, group_freqs=[gfreq],
                     capacity_tiles=int(sp.local_num_tiles[0]))
    assert int(sp.local_num_tiles[0]) == sp.capacity_tiles
    return sp


def test_hysteresis_blocks_marginal_swap_and_allows_hot_one():
    sp = _paging_scenario()
    cold = sp.cold_groups
    assert cold.size > 0
    resident = np.nonzero((sp.shard_of_group >= 0)
                          & ~sp.replicated_group)[0]
    assert resident.size >= 2
    # synthetic drifted loads with a unique, known min-load victim:
    # residents at 2.0, one victim at 1.0, replicated kept clearly hot
    # so Eq.1 churn stays out of the picture, all cold traffic zero
    # except the group under test
    victim = int(resident[0])
    singles = cold[np.asarray(sp.group_copies)[cold] == 1]
    assert singles.size > 0, "need a 1-copy cold group"
    g = int(singles[0])
    h = 2.0
    pol = PagingPolicy(capacity_tiles=sp.capacity_tiles, hysteresis=h)
    base = np.zeros(sp.num_groups, dtype=np.float64)
    base[resident] = 2.0
    base[victim] = 1.0
    base[np.asarray(sp.replicated_group)] = 50.0
    vload = 1.0

    below = base.copy()
    below[g] = 0.95 * h * vload
    p = compute_plan_patch(sp, below, eq1_batch=EQ1_BATCH, paging=pol)
    assert g not in [f[0] for f in p.fetched]
    assert victim not in p.evicted

    above = base.copy()
    above[g] = 1.5 * h * vload
    p = compute_plan_patch(sp, above, eq1_batch=EQ1_BATCH, paging=pol)
    assert g in [f[0] for f in p.fetched], p.summary()
    assert p.evicted, p.summary()
    sp2 = apply_plan_patch(sp, p)
    assert sp2.shard_of_group[g] >= 0
    assert all(sp2.shard_of_group[e] == COLD for e in p.evicted)
    # no immediate reverse swap: recomputing on the SAME loads must not
    # page the fresh evictee back in (it would need to out-load the
    # just-fetched group by the hysteresis factor — impossible)
    p2 = compute_plan_patch(sp2, above, eq1_batch=EQ1_BATCH, paging=pol)
    assert not any(f[0] in p.evicted for f in p2.fetched)
    assert g not in p2.evicted


def test_paging_patch_never_shrinks_or_grows_capacity():
    sp = _paging_scenario(seed=9)
    pol = PagingPolicy(capacity_tiles=sp.capacity_tiles, hysteresis=1.2)
    hot = sp.group_load.copy()
    if sp.cold_groups.size:
        hot[sp.cold_groups] = hot.max() * 3
    # shrink_slack is ignored under paging (fixed budget)
    p = compute_plan_patch(sp, hot, eq1_batch=EQ1_BATCH,
                           shrink_slack=0, paging=pol)
    assert p.new_capacity == sp.capacity_tiles
    assert not p.moved
    sp2 = apply_plan_patch(sp, p)
    assert int(sp2.local_num_tiles.max()) <= sp.capacity_tiles


def test_max_fetch_tiles_bounds_the_paging_dma():
    sp = _paging_scenario()
    cold = sp.cold_groups
    resident = np.nonzero((sp.shard_of_group >= 0)
                          & ~sp.replicated_group)[0]
    assert cold.size >= 2 and resident.size >= 2
    # every cold group screams, every evictable victim whispers: the
    # unbounded patch swaps as many as the free-list allows
    hot = np.zeros(sp.num_groups, dtype=np.float64)
    hot[resident] = 1.0
    hot[np.asarray(sp.replicated_group)] = 100.0
    hot[cold] = 50.0
    unbounded = compute_plan_patch(
        sp, hot, eq1_batch=EQ1_BATCH,
        paging=PagingPolicy(capacity_tiles=sp.capacity_tiles,
                            hysteresis=1.1))
    assert len(unbounded.fetch_dma) >= 2, unbounded.summary()
    bound = max(1, len(unbounded.fetch_dma) // 2)
    p = compute_plan_patch(
        sp, hot, eq1_batch=EQ1_BATCH,
        paging=PagingPolicy(capacity_tiles=sp.capacity_tiles,
                            hysteresis=1.1, max_fetch_tiles=bound))
    assert len(p.fetch_dma) <= bound < len(unbounded.fetch_dma)


# ------------------------------------- patch_shard_images edge cases --


def test_patch_images_zero_moved_tiles_is_identity():
    """An evict-only patch moves no data: the image array must come
    back byte-identical (evicted slots just stop being addressed)."""
    for seed in (13, 5, 3, 7, 11):      # need a sharded-once resident
        layout, table, fused, _unc, sp, _cap = _capped_setup(seed)
        resident = np.nonzero((sp.shard_of_group >= 0)
                              & ~sp.replicated_group)[0]
        if resident.size:
            break
    assert resident.size > 0
    images = jnp.asarray(sp.build_shard_images(fused))
    g = int(resident[np.argmin(sp.group_load[resident])])
    o = int(sp.shard_of_group[g])
    base = np.zeros(sp.num_groups, dtype=np.int64)
    np.cumsum(sp.group_copies[:-1], out=base[1:])
    tiles = range(int(base[g]), int(base[g] + sp.group_copies[g]))
    patch = PlanPatch(
        promoted=[], demoted=[], dma=[],
        freed=[(o, int(sp.local_tile_of[o, t])) for t in tiles],
        new_capacity=int(images.shape[1]),
        drifted_load=sp.group_load.copy(),
        evicted=[g], evicted_tiles=int(sp.group_copies[g]),
    )
    assert not patch.is_noop()          # residency changed, image didn't
    images2 = patch_shard_images(images, patch, fused)
    np.testing.assert_array_equal(np.asarray(images2), np.asarray(images))
    sp2 = apply_plan_patch(sp, patch)
    assert sp2.shard_of_group[g] == COLD
    assert sp2.cold_tiles == sp.cold_tiles + int(sp.group_copies[g])
    assert int(sp2.local_num_tiles[o]) == int(sp.local_num_tiles[o]) - len(
        list(tiles))
    # serving queries that avoid the evicted group stays exact
    rows = table.shape[0]
    gof = np.asarray(layout.group_of, dtype=np.int64)
    ok_rows = np.nonzero(~np.isin(gof, np.asarray(sp2.cold_groups)))[0]
    ev = [np.random.default_rng(i).choice(ok_rows, size=5).tolist()
          for i in range(8)]
    cq = compile_queries(layout, ev, replica_block=4)
    sbq = shard_block_queries(cq, sp2, 4)
    out = np.asarray(crossbar_reduce_sharded(
        images2, sbq.tile_ids, sbq.bitmaps))[: sbq.batch]
    want = np.asarray(reduce_dense_oracle(jnp.asarray(table), ev))
    np.testing.assert_array_equal(out, want)


def test_fetch_dma_scatters_from_master_image():
    """A paging patch's fetch_dma writes must land the master image's
    bytes in the fetched slots (and nothing else may change)."""
    layout, table, fused, _unc, sp, cap = _capped_setup(17)
    assert sp.cold_groups.size > 0
    # give the hot tier free headroom so fetches land in empty slots
    # (the victimless page-in path; eviction swaps are covered above)
    cap2 = cap + 4
    images = jnp.asarray(sp.build_shard_images(fused))
    pad = jnp.zeros((sp.num_shards, cap2 - images.shape[1])
                    + images.shape[2:], images.dtype)
    images = jnp.concatenate([images, pad], axis=1)
    hot = sp.group_load.copy()
    hot[sp.cold_groups] = hot.max() * 3
    p = compute_plan_patch(
        sp, hot, eq1_batch=EQ1_BATCH,
        paging=PagingPolicy(capacity_tiles=cap2, hysteresis=1.1))
    assert p.fetch_dma, p.summary()
    images2 = patch_shard_images(images, p, fused)
    touched = set()
    for s, slot, t in list(p.dma) + list(p.fetch_dma):
        np.testing.assert_array_equal(
            np.asarray(images2[s, slot]), fused[t])
        touched.add((s, slot))
    for s in range(sp.num_shards):
        for slot in range(images.shape[1]):
            if (s, slot) not in touched:
                np.testing.assert_array_equal(
                    np.asarray(images2[s, slot]), np.asarray(images[s, slot]))


def test_fetch_failure_degrades_to_host_path_and_drain_survives():
    """An injected patch-apply fault (the paging DMA seam) must leave
    the group cold — its queries keep taking the host path — and the
    drain still returns every row, bit-identical."""
    faults = FaultPlan([], seed=10).add("patch", times=100)
    oracle, capped, tables, rng = _servers(
        19, TierConfig(capacity_frac=0.5, hysteresis=1.1),
        flush_policy="deadline",
        replan=ReplanConfig(threshold=0.2, half_life=4, min_queries=32),
    )
    # rebuild capped WITH the fault plan (same everything else)
    capped2 = ShardedEmbeddingServer(
        {n: t for n, t in tables.items()},
        {n: zipf_queries(t.shape[0], 64, 5.0, seed=19 + i)
         for i, (n, t) in enumerate(tables.items())},
        num_shards=2, q_block=4, group_size=16, batch_size=16,
        tiers=TierConfig(capacity_frac=0.5, hysteresis=1.1),
        flush_policy="deadline",
        replan=ReplanConfig(threshold=0.2, half_life=4, min_queries=32),
        retry=RetryPolicy(patch_retries=1, backoff_base=0.0, jitter=0.0),
        faults=faults,
    )
    cold = capped2.plan.cold_groups
    gof = capped2._residency._fused_group_of_row["a"]
    cold_rows = np.nonzero(np.isin(gof, cold))[0]
    rows = tables["a"].shape[0]
    got, want = [], []
    for i in range(300):
        if i % 3:
            q = rng.choice(cold_rows[:40], size=rng.integers(1, 5)).tolist()
        else:
            q = rng.integers(0, rows, size=rng.integers(1, 5)).tolist()
        capped2.submit("a", q)
        oracle.submit("a", q)
        if (i + 1) % 100 == 0:
            g, w = capped2.drain(), oracle.drain()
            got.append(np.asarray(g["a"]))
            want.append(np.asarray(w["a"]))
    np.testing.assert_array_equal(np.concatenate(got), np.concatenate(want))
    # every patch apply failed: nothing ever paged in, the ledger shows
    # the failures, and the hot tier never changed shape
    assert capped2.stats.ledger.patch_failures > 0
    assert capped2.stats.fetched_tiles == 0
    assert np.array_equal(capped2.plan.cold_groups, cold)


# ------------------------------------------------- routing + scheduler --


def test_scheduler_raises_on_cold_query():
    layout, _table, _fused, _unc, sp, _cap = _capped_setup(23)
    assert sp.cold_groups.size > 0
    sched = FlushScheduler(
        sp, [layout], ["t0"], 4,
        FlushPolicy.parse("per-shard", batch_size=8))
    gof = np.asarray(layout.group_of, dtype=np.int64)
    cold_rows = np.nonzero(np.isin(gof, np.asarray(sp.cold_groups)))[0]
    assert cold_rows.size > 0
    with pytest.raises(ValueError, match="cold"):
        sched.push("t0", 0, cold_rows[:3].tolist())
    hot_rows = np.nonzero(~np.isin(gof, np.asarray(sp.cold_groups)))[0]
    sched.push("t0", 0, hot_rows[:3].tolist())  # hot queries still route


def test_resident_query_survives_patch_barrier_during_routing():
    """Regression (stale-residency race): a query judged resident whose
    own routing's host flush hits a patch barrier — which evicts that
    query's group — must detour to the host path under the post-patch
    residency, not land in the scheduler and raise on the cold group."""
    oracle, capped, tables, rng = _servers(
        47, TierConfig(capacity_frac=0.5, host_batch=64, host_deadline=8),
        flush_policy="per-shard",
    )
    plan = capped.plan
    cold = plan.cold_groups
    assert cold.size > 0
    resident = np.nonzero((plan.shard_of_group >= 0)
                          & ~plan.replicated_group)[0]
    gof = capped._residency._fused_group_of_row["a"]
    in_a = resident[np.isin(resident, gof)]
    assert in_a.size > 0
    # craft the paging patch the barrier will apply: every cold group
    # screams, the victim (a resident group with rows in "a") whispers,
    # replicated groups stay clearly hot — the victim must be evicted
    victim = int(in_a[0])
    loads = np.zeros(plan.num_groups, dtype=np.float64)
    loads[resident] = 2.0
    loads[np.asarray(plan.replicated_group)] = 50.0
    loads[cold] = 50.0
    loads[victim] = 0.01
    pol = PagingPolicy(capacity_tiles=capped._capacity_tiles,
                       hysteresis=1.1)
    patch = compute_plan_patch(plan, loads, eq1_batch=EQ1_BATCH,
                               paging=pol)
    assert victim in patch.evicted, patch.summary()
    victim_rows = np.nonzero(gof == victim)[0]
    cold_rows = np.nonzero(np.isin(gof, cold))[0]
    # one queued cold query aged past its deadline + a staged patch:
    # the NEXT submission's routing fires the host flush → barrier
    q0 = cold_rows[:2].tolist()
    capped.submit("a", q0)
    oracle.submit("a", q0)
    capped._tick += 100
    capped._staged = patch
    q1 = victim_rows[:3].tolist()
    capped.submit("a", q1)      # pre-fix: ValueError('… cold …')
    oracle.submit("a", q1)
    # the barrier ran mid-routing and the in-hand query went cold
    assert capped.stats.barrier_flushes >= 1
    assert not capped._residency.is_resident(
        "a", np.asarray(q1, dtype=np.int64))
    assert capped.stats.host_queries >= 2
    got, want = capped.drain(), oracle.drain()
    np.testing.assert_array_equal(
        np.asarray(got["a"]), np.asarray(want["a"]))
    capped.close(), oracle.close()


def test_residency_index_and_host_queue():
    layout, _t, _f, _unc, sp, _cap = _capped_setup(29)
    gof = np.asarray(layout.group_of, dtype=np.int64)
    idx = ResidencyIndex(sp, {"t": gof})
    assert idx.any_cold
    cold_rows = np.nonzero(np.isin(gof, np.asarray(sp.cold_groups)))[0]
    hot_rows = np.nonzero(~np.isin(gof, np.asarray(sp.cold_groups)))[0]
    assert not idx.is_resident("t", cold_rows[:2])
    assert idx.is_resident("t", hot_rows[:2])
    # host loads count DISTINCT rows per query per group
    r = int(cold_rows[0])
    loads = idx.host_group_loads([("t", 0, np.asarray([r, r, r]))])
    assert loads.sum() == 1.0 and loads[gof[r]] == 1.0

    q = HostFetchQueue(batch=2, deadline=10)
    assert q.due(0) is None
    q.push("t", 0, np.asarray([1]), 5)
    assert q.due(5) is None
    assert q.due(15) == "deadline"      # oldest aged out
    q.push("t", 1, np.asarray([2]), 6)
    assert q.due(6) == "batch"          # batch trigger wins
    assert len(q.take()) == 2 and q.due(99) is None


def test_host_queue_deadline_forces_flush_in_hot_stream():
    """One cold query in a hot-dominated stream must still be served
    within the host deadline (ticks advance on every submission)."""
    oracle, capped, tables, rng = _servers(
        31, TierConfig(capacity_frac=0.5, host_batch=64, host_deadline=20),
        flush_policy="deadline",
    )
    cold = capped.plan.cold_groups
    gof = capped._residency._fused_group_of_row["a"]
    cold_rows = np.nonzero(np.isin(gof, cold))[0]
    hot_rows = np.nonzero(~np.isin(gof, cold))[0]
    capped.submit("a", cold_rows[:2].tolist())
    for i in range(30):
        capped.submit("a", rng.choice(hot_rows, size=3).tolist())
    assert capped.stats.host_deadline_flushes >= 1
    assert len(capped._host_queue) == 0
    capped.drain()
    capped.close()


# --------------------------------------- observation + dispatch caches --


def test_load_observation_cache_is_content_keyed():
    rows = 192
    hist = zipf_queries(rows, 48, 6.0, seed=2)
    layout, plan, gfreq = _pipeline(rows, hist)
    sp = plan_shards([layout], [plan], 2, group_freqs=[gfreq])
    tile_group = np.repeat(np.arange(sp.num_groups), sp.group_copies)
    ev1 = zipf_queries(rows, 8, 6.0, seed=3)
    ev2 = zipf_queries(rows, 8, 6.0, seed=4)
    cq1 = compile_queries(layout, ev1, replica_block=4)
    cq2 = compile_queries(layout, ev2, replica_block=4)
    cache = LoadObservationCache(maxsize=4)
    a = cache.loads(cq1, tile_group, sp.num_groups)
    b = cache.loads(cq1, tile_group, sp.num_groups)   # identical content
    c = cache.loads(cq2, tile_group, sp.num_groups)   # different queries
    assert cache.hits == 1 and cache.misses == 2
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c) or ev1 == ev2
    # eviction bound holds
    for seed in range(5, 12):
        ev = zipf_queries(rows, 8, 6.0, seed=seed)
        cache.loads(compile_queries(layout, ev, replica_block=4),
                    tile_group, sp.num_groups)
    assert len(cache._memo) <= 4


def test_server_memoizes_repeated_flush_observation():
    """Replaying the SAME batch through the server must hit the memo."""
    rows, dim = 256, 128
    tables = {"a": _int_table(rows, dim, 41)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=42)}
    server = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=8,
        replan=ReplanConfig(threshold=0.9, half_life=4, min_queries=10**9),
    )
    batch = [list(range(5 * i, 5 * i + 5)) for i in range(8)]
    server.serve({"a": batch})
    server.serve({"a": batch})
    server.serve({"a": batch})
    assert server.stats.load_obs_misses == 1
    assert server.stats.load_obs_hits == 2
    s = server.stats.summary()["tiers"]
    assert s["load_obs_hits"] == 2 and s["load_obs_misses"] == 1


def test_dispatch_caches_bounded_and_reported():
    clear_dispatch_caches()
    stats = dispatch_cache_stats()
    assert set(stats) >= {"emulated", "mesh", "mesh_subset",
                          "mesh_single", "total"}
    for k in ("emulated", "mesh", "mesh_subset", "mesh_single"):
        assert stats[k]["maxsize"] == DISPATCH_CACHE_MAXSIZE
        assert stats[k]["currsize"] == 0
    # two emulated dispatches with identical signatures: 1 miss + 1 hit
    layout, table, fused, _unc, sp, _cap = _capped_setup(37, cap_frac=1.0)
    images = jnp.asarray(sp.build_shard_images(fused))
    ev = zipf_queries(192, 6, 6.0, seed=38)
    cq = compile_queries(layout, ev, replica_block=4)
    sbq = shard_block_queries(cq, sp, 4)
    crossbar_reduce_sharded(images, sbq.tile_ids, sbq.bitmaps)
    crossbar_reduce_sharded(images, sbq.tile_ids, sbq.bitmaps)
    stats = dispatch_cache_stats()
    assert stats["emulated"]["misses"] >= 1
    assert stats["emulated"]["hits"] >= 1
    assert stats["total"]["hits"] >= 1
    # the server surfaces the same counters
    tables = {"a": _int_table(256, 128, 43)}
    histories = {"a": zipf_queries(256, 48, 5.0, seed=44)}
    server = ShardedEmbeddingServer(tables, histories, num_shards=2,
                                    group_size=16, batch_size=8)
    rep = server.report()
    assert "dispatch_cache" in rep
    assert rep["dispatch_cache"]["emulated"]["maxsize"] == (
        DISPATCH_CACHE_MAXSIZE)
