"""Fused int8 flash-decode attention kernel vs its jnp oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import fused_decode_attention_pallas, fused_decode_attention_ref


def _case(b, S, kvh, g, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)).astype(np.float32))
    k = rng.normal(size=(b, S, kvh, hd)).astype(np.float32)
    v = rng.normal(size=(b, S, kvh, hd)).astype(np.float32)
    k_s = (np.abs(k).max(-1) / 127 + 1e-8).astype(np.float32)
    v_s = (np.abs(v).max(-1) / 127 + 1e-8).astype(np.float32)
    k_q = jnp.asarray(np.round(k / k_s[..., None]).astype(np.int8))
    v_q = jnp.asarray(np.round(v / v_s[..., None]).astype(np.int8))
    return q, k_q, jnp.asarray(k_s), v_q, jnp.asarray(v_s)


@pytest.mark.parametrize("b,S,kvh,g,hd,block_s,length", [
    (1, 256, 1, 1, 128, 128, 100),
    (2, 1024, 2, 4, 128, 256, 700),
    (2, 512, 4, 2, 64, 128, 512),     # full cache valid
    (1, 512, 2, 8, 256, 512, 1),      # single valid position
])
def test_fused_decode_attention_matches_ref(b, S, kvh, g, hd, block_s, length):
    q, k_q, k_s, v_q, v_s = _case(b, S, kvh, g, hd, seed=S + hd)
    ln = jnp.asarray(length, jnp.int32)
    out_k, m_k, l_k = fused_decode_attention_pallas(
        q, k_q, k_s, v_q, v_s, ln, block_s=block_s
    )
    out_r, m_r, l_r = fused_decode_attention_ref(q, k_q, k_s, v_q, v_s, ln)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r), rtol=1e-4, atol=1e-4)
    fin_k = np.asarray(out_k / l_k[..., None])
    fin_r = np.asarray(out_r / l_r[..., None])
    np.testing.assert_allclose(fin_k, fin_r, atol=1e-4, rtol=1e-4)


def test_fused_decode_matches_bf16_attention_within_quant_error():
    """End to end: kernel over the quantized cache ≈ exact bf16 attention."""
    b, S, kvh, g, hd = 1, 512, 2, 2, 128
    rng = np.random.default_rng(3)
    q, k_q, k_s, v_q, v_s = _case(b, S, kvh, g, hd, seed=3)
    ln = jnp.asarray(300, jnp.int32)
    out_k, m_k, l_k = fused_decode_attention_pallas(q, k_q, k_s, v_q, v_s, ln)
    approx = np.asarray(out_k / l_k[..., None])

    # exact attention over the dequantized (≈original) cache
    k = np.asarray(k_q, np.float32) * np.asarray(k_s)[..., None]
    v = np.asarray(v_q, np.float32) * np.asarray(v_s)[..., None]
    s = np.einsum("bkgd,btkd->bkgt", np.asarray(q), k) / np.sqrt(hd)
    s[..., 300:] = -1e30
    w = np.exp(s - s.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    exact = np.einsum("bkgt,btkd->bkgd", w, v)
    np.testing.assert_allclose(approx, exact, atol=1e-3, rtol=1e-3)


def test_fused_decode_block_size_invariance():
    q, k_q, k_s, v_q, v_s = _case(1, 1024, 1, 2, 128, seed=9)
    ln = jnp.asarray(777, jnp.int32)
    outs = []
    for bs in (128, 256, 512):
        o, m, l = fused_decode_attention_pallas(q, k_q, k_s, v_q, v_s, ln, block_s=bs)
        outs.append(np.asarray(o / l[..., None]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)
