"""Integration: fault-tolerant training end to end, data determinism,
DLRM training through the kernel datapath."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import QueryBatcher, TokenBatcher
from repro.models import init_lm
from repro.train import checkpoint as ckpt
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import AdamW


def _mk(arch="xlstm-125m"):
    cfg = get_config(arch, smoke=True)
    opt = AdamW(schedule=lambda s: 1e-3)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    data = TokenBatcher(cfg.vocab_size, batch_size=4, seq_len=16, seed=0)
    return cfg, opt, state, step, data


def _run(state, step, data, steps, start=0, ckpt_dir=None, save_every=5,
         crash_at=None):
    for s in range(start, steps):
        if crash_at is not None and s == crash_at:
            raise RuntimeError("injected failure")
        tokens, labels = data.batch(s)
        state, m = step(state, {"tokens": tokens, "labels": labels})
        if ckpt_dir and (s + 1) % save_every == 0:
            ckpt.save(ckpt_dir, s + 1, state)
    return state


def test_crash_restore_resume_bitexact(tmp_path):
    """Train 12 steps clean vs crash-at-8 + restore-from-5 + replay:
    the deterministic pipeline and checkpoint must make them identical."""
    cfg, opt, state0, step, data = _mk()
    clean = _run(state0, step, data, 12)

    d = str(tmp_path)
    cfg2, opt2, state2, step2, data2 = _mk()
    with pytest.raises(RuntimeError):
        _run(state2, step2, data2, 12, ckpt_dir=d, crash_at=8)
    latest = ckpt.latest_step(d)
    assert latest == 5
    like = jax.eval_shape(lambda: state2)
    restored = ckpt.restore(d, latest, like)
    resumed = _run(restored, step2, data2, 12, start=latest)

    for a, b in zip(jax.tree.leaves(clean.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_token_batcher_deterministic_and_host_sharded():
    d = TokenBatcher(vocab_size=100, batch_size=8, seq_len=16, seed=3)
    a1, b1 = d.batch(7)
    a2, b2 = d.batch(7)
    np.testing.assert_array_equal(a1, a2)
    # host shards are disjoint derivations (different streams per host)
    h0 = TokenBatcher(100, 8, 16, seed=3, host_index=0, num_hosts=2)
    h1 = TokenBatcher(100, 8, 16, seed=3, host_index=1, num_hosts=2)
    t0, _ = h0.batch(0)
    t1, _ = h1.batch(0)
    assert t0.shape == (4, 16)
    assert not np.array_equal(t0, t1)


def test_query_batcher_shard_sizes():
    qb = QueryBatcher(num_rows=512, batch_size=64, mean_bag=8.0,
                      host_index=1, num_hosts=4)
    batch = qb.batch(0)
    assert len(batch) == 16
    assert all(q.max() < 512 for q in batch)


def test_microbatched_step_matches_single_batch():
    """Grad accumulation must give (numerically close) same update."""
    cfg = get_config("minicpm-2b", smoke=True)
    opt = AdamW(schedule=lambda s: 1e-3)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    s1 = init_train_state(params, opt)
    s1, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(s1, batch)
    s2 = init_train_state(params, opt)
    s2, m2 = jax.jit(make_train_step(cfg, opt, microbatches=4))(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
