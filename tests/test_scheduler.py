"""Shard-aware async serving (DESIGN.md §7): independent per-shard
flushes with double-buffered host-compile / device-execute pipelining
must serve BIT-IDENTICAL outputs to the synchronous global path (and the
dense oracle), and a PlanPatch staged during in-flight flushes must
apply atomically at the next barrier — never mid-pipeline.

Bit-identity is pinned on integer-valued float tables (every partial sum
exact in f32), so what the tests reject is a dropped, duplicated or
mis-routed query after the engine reorders flushes — the failure modes
of broken routing/ownership.  The patch-barrier invariants come from
DESIGN.md §7.3: pending work flushes under the plan it was submitted
against, the pipeline drains, and only then do placement arrays swap.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    BlockUnionTracker,
    build_cooccurrence,
    build_layout,
    compile_queries,
    correlation_aware_grouping,
    plan_replication,
    shard_block_queries,
)
from repro.core.reduction import reduce_dense_oracle
from repro.data import zipf_queries
from repro.dist import build_fused_image, plan_shards
from repro.kernels import crossbar_reduce_sharded
from repro.serve import FlushPolicy, RetryPolicy, ShardedEmbeddingServer
from repro.serve.drift import ReplanConfig

EQ1_BATCH = 64


def _int_table(rows, dim, seed):
    """Integer-valued f32 table: partial sums are exact in float32."""
    return np.random.default_rng(seed).integers(
        -8, 9, size=(rows, dim)
    ).astype(np.float32)


def _pipeline(rows, hist, *, group_size=16, dim=128):
    g = build_cooccurrence(hist, rows)
    grouping = correlation_aware_grouping(g, group_size)
    plan = plan_replication(grouping, g.freq, EQ1_BATCH)
    layout = build_layout(grouping, plan, dim)
    return layout, plan, grouping.group_freq(g.freq)


# ------------------------------------------------ subset block compile --


def test_subset_compile_owns_each_activation_once():
    """participants= restricts the stack to the subset; every activation
    lands on exactly one participating shard, replicated-tile ownership
    round-robins over the participants, and summing the subset kernels
    over a partition of the batch reproduces the oracle exactly."""
    rows, dim, S = 192, 128, 2
    hist = zipf_queries(rows, 48, 6.0, seed=0)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, 0)
    fused = build_fused_image([layout], [table])
    sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
    images = jnp.asarray(sp.build_shard_images(fused))
    ev = zipf_queries(rows, 12, 6.0, seed=1)

    # route queries by owner set (the scheduler's rule)
    owner_of_row = sp.shard_of_group[layout.group_of]
    by_home = {0: [], 1: [], None: []}
    for q in ev:
        owners = {int(o) for o in
                  np.unique(owner_of_row[np.unique(np.asarray(q, np.int64))])
                  if o >= 0}
        if len(owners) <= 1:
            by_home[owners.pop() if owners else 0].append(q)
        else:
            by_home[None].append(q)

    outs, queries = [], []
    for home in (0, 1):
        if not by_home[home]:
            continue
        cq = compile_queries(layout, by_home[home], replica_block=4)
        sbq = shard_block_queries(cq, sp, 4, participants=[home])
        assert sbq.tile_ids.shape[0] == 1
        assert sbq.shard_ids.tolist() == [home]
        # every bitmap row lives in the single participant's stack slot
        out = np.asarray(crossbar_reduce_sharded(
            images, sbq.tile_ids, sbq.bitmaps, shard_ids=sbq.shards
        ))[: sbq.batch]
        outs.append(out)
        queries.extend(by_home[home])
    if by_home[None]:
        cq = compile_queries(layout, by_home[None], replica_block=4)
        sbq = shard_block_queries(cq, sp, 4)
        outs.append(np.asarray(crossbar_reduce_sharded(
            images, sbq.tile_ids, sbq.bitmaps
        ))[: sbq.batch])
        queries.extend(by_home[None])
    got = np.concatenate(outs)
    want = np.asarray(reduce_dense_oracle(jnp.asarray(table), queries))
    np.testing.assert_array_equal(got, want)


def test_subset_compile_rejects_foreign_owners():
    """A query whose sharded-once groups live outside the participants
    must fail the compile loudly, not silently drop activations."""
    rows = 192
    hist = zipf_queries(rows, 48, 6.0, seed=2)
    layout, plan, gfreq = _pipeline(rows, hist)
    sp = plan_shards([layout], [plan], 2, group_freqs=[gfreq])
    owner_of_row = sp.shard_of_group[layout.group_of]
    ev = zipf_queries(rows, 24, 6.0, seed=3)
    multi = [q for q in ev if len({
        int(o) for o in np.unique(owner_of_row[np.unique(np.asarray(q, np.int64))])
        if o >= 0
    }) > 1]
    if not multi:
        return  # vacuous at this seed
    cq = compile_queries(layout, multi[:1], replica_block=4)
    with pytest.raises(ValueError, match="non-participating"):
        shard_block_queries(cq, sp, 4, participants=[0])


def test_subset_dispatch_matches_full_under_shard_ids():
    """crossbar_reduce_sharded with shard_ids= must equal the same
    batch compiled/dispatched through the full-stack path."""
    rows, dim, S = 192, 128, 4
    hist = zipf_queries(rows, 48, 6.0, seed=4)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    table = _int_table(rows, dim, 4)
    fused = build_fused_image([layout], [table])
    sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
    images = jnp.asarray(sp.build_shard_images(fused))
    ev = zipf_queries(rows, 9, 6.0, seed=5)
    cq = compile_queries(layout, ev, replica_block=4)
    full = np.asarray(crossbar_reduce_sharded(
        images, *(lambda s: (s.tile_ids, s.bitmaps))(
            shard_block_queries(cq, sp, 4))
    ))[: len(ev)]
    sub = shard_block_queries(cq, sp, 4, participants=list(range(S)))
    got = np.asarray(crossbar_reduce_sharded(
        images, sub.tile_ids, sub.bitmaps, shard_ids=sub.shards
    ))[: len(ev)]
    np.testing.assert_array_equal(got, full)


# -------------------------------------------------- union-fill tracker --


def test_union_tracker_matches_compiled_grid():
    """The incremental fill accounting must agree with what
    shard_block_queries actually compiles for a single-shard stream."""
    rows = 192
    hist = zipf_queries(rows, 48, 6.0, seed=6)
    layout, plan, gfreq = _pipeline(rows, hist)
    sp = plan_shards([layout], [plan], 1, group_freqs=[gfreq])
    ev = zipf_queries(rows, 13, 6.0, seed=7)
    tr = BlockUnionTracker(4)
    for q in ev:
        rows_u = np.unique(np.asarray(q, np.int64))
        tr.add(np.unique(layout.group_of[rows_u]).tolist())
    cq = compile_queries(layout, ev, replica_block=4)
    sbq = shard_block_queries(cq, sp, 4, participants=[0])
    assert tr.pending == len(ev)
    assert tr.grid_cells() == sbq.grid_cells_per_shard()
    tr.reset()
    assert tr.fill == 0 and tr.grid_cells() == 0


def test_flush_policy_validation():
    with pytest.raises(ValueError, match="unknown flush policy"):
        FlushPolicy(kind="sometimes")
    with pytest.raises(ValueError, match="max_in_flight"):
        FlushPolicy(kind="per-shard", max_in_flight=0)
    p = FlushPolicy.parse("deadline", batch_size=32)
    assert p.batch_size == 32 and p.deadline == 128 and p.is_async
    assert not FlushPolicy.parse("global", batch_size=8).is_async


# -------------------------------------------- async ≡ sync bit-identity --


@pytest.mark.parametrize("num_shards", [1, 2, 4])
@pytest.mark.parametrize("policy", ["per-shard", "deadline"])
def test_async_serving_bit_identical_to_sync(num_shards, policy):
    rows, dim = 160, 128
    rng = np.random.default_rng(10)
    tables = {"a": _int_table(rows, dim, 11), "b": _int_table(rows, dim, 12)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=13),
                 "b": zipf_queries(rows, 48, 5.0, seed=14)}
    streams = {"a": zipf_queries(rows, 30, 5.0, seed=15),
               "b": zipf_queries(rows, 17, 5.0, seed=16)}
    # skewed interleave: a arrives ~2x as often as b
    replay, ia, ib = [], 0, 0
    for i in range(len(streams["a"]) + len(streams["b"])):
        if (i % 3 < 2 and ia < len(streams["a"])) or ib >= len(streams["b"]):
            replay.append(("a", streams["a"][ia])); ia += 1
        else:
            replay.append(("b", streams["b"][ib])); ib += 1

    def run(policy, **kw):
        srv = ShardedEmbeddingServer(
            tables, histories, num_shards=num_shards, q_block=4,
            group_size=16, batch_size=8, flush_policy=policy, **kw,
        )
        outs = {n: [] for n in tables}
        for name, q in replay:
            for n, o in srv.submit(name, q).items():
                outs[n].append(np.asarray(o))
        for n, o in srv.flush().items():
            outs[n].append(np.asarray(o))
        return srv, {n: np.concatenate(v) for n, v in outs.items() if v}

    srv_g, outs_g = run("global")
    srv_a, outs_a = run(policy, max_in_flight=2, flush_deadline=20)
    for n in tables:
        np.testing.assert_array_equal(outs_a[n], outs_g[n])
        want = np.asarray(reduce_dense_oracle(
            jnp.asarray(tables[n]), streams[n]))
        np.testing.assert_array_equal(outs_a[n], want)
    st = srv_a.stats.summary()
    assert st["flush_policy"] == policy
    assert st["batches"] >= 1
    assert st["in_flight_peak"] >= 1
    if policy == "deadline" and num_shards > 1:
        # the skewed slow table must never wait unboundedly
        assert st["batches"] >= srv_g.stats.summary()["batches"]


def test_async_drain_orders_rows_by_submission():
    """drain() must return rows in per-table submission order even when
    homes flush out of order."""
    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 20)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=21)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=4, flush_policy="per-shard",
    )
    stream = zipf_queries(rows, 23, 5.0, seed=22)
    for q in stream:
        srv.submit("a", q)
    out = srv.drain()
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)
    # second drain with no traffic returns nothing
    assert srv.drain() == {}


def test_failed_async_flush_requeues_batch():
    """A failed flush must not drop its batch: a malformed query is
    rejected at routing time (nothing enqueued), and a dispatch-time
    failure requeues the whole batch for retry — the async analogue of
    the sync flush's leave-buffered-on-failure contract.  Pinned on
    ``RetryPolicy.legacy()``: the default self-healing policy retries
    in place instead of requeue-and-re-raise (test_faults.py)."""
    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 40)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=41)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=1, q_block=4, group_size=16,
        batch_size=8, flush_policy="per-shard", retry=RetryPolicy.legacy(),
    )
    good = zipf_queries(rows, 7, 5.0, seed=42)
    for q in good:
        srv.submit("a", q)
    # malformed query: rejected at the door, buffered work untouched
    with pytest.raises(IndexError):
        srv.submit("a", [rows + 5])
    assert srv.scheduler.pending_total() == 7
    # transient dispatch failure at the flush trigger: batch requeues
    calls = {"n": 0}
    orig = srv._compile_and_dispatch

    def flaky(entries, participants):
        if calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("transient device error")
        return orig(entries, participants)

    srv._compile_and_dispatch = flaky
    last = zipf_queries(rows, 1, 5.0, seed=43)[0]
    with pytest.raises(RuntimeError):
        srv.submit("a", last)  # trips batch_size → flush → fails
    assert srv.scheduler.pending_total() == 8, "failed flush dropped queries"
    # retry (drain) succeeds and rows stay in submission order
    out = srv.drain()
    stream = list(good) + [last]
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)


# --------------------------------------------- engine accounting fixes --


def test_device_busy_ignores_unknown_array_types():
    """hidden_compile_s promises a conservative LOWER bound: an output
    without is_ready (e.g. a materialized NumPy array from a stubbed
    dispatch) must count as idle, not busy — the old AttributeError
    branch overcounted hidden compile exactly where it mattered."""
    from repro.serve.sharded import _InFlight

    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 50)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=51)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=1, q_block=4, group_size=16,
        batch_size=8, flush_policy="per-shard",
    )
    stub = _InFlight(outs=[np.zeros((4, dim), np.float32)], sbq=None,
                     served=["a"], seqs={}, t0=0.0, n_queries=1)
    srv._in_flight.append(stub)
    assert srv._device_busy() is False, (
        "array without is_ready treated as busy — overcounts overlap"
    )
    assert srv._entry_ready(stub)

    class _NotReady:
        def is_ready(self):
            return False

    srv._in_flight.append(_InFlight(
        outs=[_NotReady()], sbq=None, served=["a"], seqs={}, t0=0.0,
        n_queries=1,
    ))
    assert srv._device_busy() is True
    srv._in_flight.clear()


def test_in_flight_peak_sampled_at_append():
    """The queue transiently holds max_in_flight + 1 entries before the
    retire loop trims it; the peak stat must report that transient, not
    the post-trim depth (which can never exceed the bound)."""
    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 52)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=53)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=1, q_block=4, group_size=16,
        batch_size=4, flush_policy="per-shard", max_in_flight=1,
    )
    stream = zipf_queries(rows, 12, 5.0, seed=54)  # >= 3 flushes
    for q in stream:
        srv.submit("a", q)
    out = srv.drain()
    assert srv.stats.batches >= 2
    assert srv.stats.in_flight_peak == 2, (
        f"peak {srv.stats.in_flight_peak} != max_in_flight + 1 — "
        "sampled after the retire loop trimmed the queue"
    )
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)


@pytest.mark.parametrize("policy", ["global", "per-shard"])
def test_submit_validates_ids_before_enqueue(policy):
    """Malformed queries are rejected at the door: no buffer entry, no
    scheduler entry, and — crucially — no sequence id consumed, so the
    pending stream stays retryable without a removal API."""
    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 55)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=56)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=64, flush_policy=policy,
    )
    good = zipf_queries(rows, 5, 5.0, seed=57)
    for q in good:
        srv.submit("a", q)
    for bad in ([rows], [rows + 5], [-1], [0, rows + 2]):
        with pytest.raises(IndexError, match="out of range"):
            srv.submit("a", bad)
    if srv.scheduler is not None:
        assert srv.scheduler.pending_total() == len(good)
        assert srv.next_seq("a") == len(good), "rejected query consumed a seq"
    else:
        assert srv._buffered == len(good)
    out = srv.flush()
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), good))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)


def test_seq_reset_guarded_by_requeued_entries():
    """drain() restarts sequence ids ONLY when nothing requeued is still
    carrying the old ones — a reset with a failed flush's entries alive
    would hand new submissions colliding seqs and scramble the argsort
    row order of the next drain.  Pinned on ``RetryPolicy.legacy()``:
    only the legacy policy requeues (healing retries in place)."""
    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 58)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=59)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=1, q_block=4, group_size=16,
        batch_size=8, flush_policy="per-shard", retry=RetryPolicy.legacy(),
    )
    good = zipf_queries(rows, 7, 5.0, seed=60)
    for q in good:
        srv.submit("a", q)
    orig = srv._compile_and_dispatch

    def broken(entries, participants):
        raise RuntimeError("persistent device error")

    srv._compile_and_dispatch = broken
    last = zipf_queries(rows, 1, 5.0, seed=61)[0]
    with pytest.raises(RuntimeError):
        srv.submit("a", last)  # trips the flush → fails → requeues
    assert srv.scheduler.pending_total() == 8
    assert srv.next_seq("a") == 8
    # a barrier that hands back without flushing (the partial-recovery
    # hazard) must not let drain() reset seqs over live requeued work
    orig_barrier = srv._barrier
    srv._barrier = lambda: None
    assert srv.drain() == {}
    assert srv.next_seq("a") == 8, "seq reset while requeued entries alive"
    srv._barrier = orig_barrier
    srv._compile_and_dispatch = orig
    more = zipf_queries(rows, 3, 5.0, seed=62)
    for q in more:
        srv.submit("a", q)
    out = srv.drain()
    stream = list(good) + [last] + list(more)
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)
    assert srv.next_seq("a") == 0  # clean drain: seqs restart


def test_route_is_a_peek():
    """route() must not consume round-robin state: inspecting a query's
    home twice returns the same answer, and only push() advances."""
    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 45)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=46)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=64, batch_size_for_eq1=512, flush_policy="per-shard",
    )
    sched = srv.scheduler
    owner = sched._owner_of_row["a"]
    repl_rows = np.nonzero(owner < 0)[0]
    if repl_rows.size == 0:
        return  # no replicated groups at this seed; vacuous
    q = [int(repl_rows[0])]
    h1, _ = sched.route("a", q)
    h2, _ = sched.route("a", q)
    assert h1 == h2, "route() consumed round-robin state"
    assert sched.push("a", 0, q) == h1
    # after the push the round robin advanced: next replicated-only
    # query routes to the other shard
    h3, _ = sched.route("a", q)
    assert h3 == (h1 + 1) % 2


# ------------------------------------------------- owner-set routing --


def _owner_rows(sched, table):
    """{owner shard: [row ids]} of the sharded-once rows of a table."""
    owner = sched._owner_of_row[table]
    out = {}
    for r, o in enumerate(owner):
        if o >= 0:
            out.setdefault(int(o), []).append(r)
    return out


def test_owner_set_scheduler_routes_by_frozen_owner_set():
    """Under owner-set routing each distinct multi-owner set is its own
    home (a sorted tuple) and take() returns exactly that set as flush
    participants — the full stack only when the set covers the mesh."""
    rows, dim, S = 160, 128, 4
    tables = {"a": _int_table(rows, dim, 63)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=64)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=S, q_block=4, group_size=16,
        batch_size=1024, flush_policy="owner-set",
    )
    sched = srv.scheduler
    by_owner = _owner_rows(sched, "a")
    if len(by_owner) < 2:
        return  # vacuous at this seed
    owners = sorted(by_owner)
    a, b = owners[0], owners[1]
    q2 = [by_owner[a][0], by_owner[b][0]]
    home, _ = sched.route("a", q2)
    assert home == (a, b)
    assert sched.push("a", 0, q2) == (a, b)
    entries, participants = sched.take((a, b))
    assert [e[2] for e in entries] == [q2]
    assert participants == [a, b]
    # single-owner queries still route to int homes
    h1, _ = sched.route("a", [by_owner[a][0]])
    assert h1 == a
    if len(by_owner) == S:
        qall = [by_owner[o][0] for o in owners]
        homeall, _ = sched.route("a", qall)
        assert homeall == tuple(owners)
        sched.push("a", 1, qall)
        _, parts = sched.take(tuple(owners))
        assert parts is None  # covers the mesh → full stack


def test_owner_set_max_pools_wide_sets():
    """Owner sets larger than owner_set_max collapse into the POOL home
    (flushed over their owner union) while sets within the cap keep
    their own — the fragmentation guard for near-mesh traffic."""
    from repro.serve.scheduler import POOL

    rows, dim, S = 160, 128, 4
    tables = {"a": _int_table(rows, dim, 73)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=74)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=S, q_block=4, group_size=16,
        batch_size=1024, flush_policy="owner-set", owner_set_max=2,
    )
    assert srv.policy.owner_set_max == 2
    sched = srv.scheduler
    by_owner = _owner_rows(sched, "a")
    if len(by_owner) < 3:
        return  # vacuous at this seed
    owners = sorted(by_owner)
    a, b, c = owners[:3]
    home2, _ = sched.route("a", [by_owner[a][0], by_owner[b][0]])
    assert home2 == (a, b)  # within the cap: keyed home
    home3, _ = sched.route("a", [by_owner[o][0] for o in (a, b, c)])
    assert home3 == POOL    # beyond the cap: pooled
    sched.push("a", 0, [by_owner[o][0] for o in (a, b, c)])
    _, parts = sched.take(POOL)
    assert parts == [a, b, c]  # pool still flushes over the owner union
    with pytest.raises(ValueError, match="owner_set_max"):
        FlushPolicy(kind="owner-set", owner_set_max=1)


@pytest.mark.parametrize("num_shards", [1, 2, 4])
@pytest.mark.parametrize("threaded", [False, True])
def test_owner_set_serving_bit_identical_to_sync(num_shards, threaded):
    """Owner-set homes (and the thread driver on top of them) must serve
    bit-identically to the synchronous global path and the oracle."""
    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 11), "b": _int_table(rows, dim, 12)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=13),
                 "b": zipf_queries(rows, 48, 5.0, seed=14)}
    streams = {"a": zipf_queries(rows, 30, 5.0, seed=15),
               "b": zipf_queries(rows, 17, 5.0, seed=16)}
    replay, ia, ib = [], 0, 0
    for i in range(len(streams["a"]) + len(streams["b"])):
        if (i % 3 < 2 and ia < len(streams["a"])) or ib >= len(streams["b"]):
            replay.append(("a", streams["a"][ia])); ia += 1
        else:
            replay.append(("b", streams["b"][ib])); ib += 1

    def run(policy, **kw):
        srv = ShardedEmbeddingServer(
            tables, histories, num_shards=num_shards, q_block=4,
            group_size=16, batch_size=8, flush_policy=policy, **kw,
        )
        outs = {n: [] for n in tables}
        for name, q in replay:
            for n, o in srv.submit(name, q).items():
                outs[n].append(np.asarray(o))
        for n, o in srv.flush().items():
            outs[n].append(np.asarray(o))
        srv.close()
        return srv, {n: np.concatenate(v) for n, v in outs.items() if v}

    srv_g, outs_g = run("global")
    srv_o, outs_o = run("owner-set", threaded=threaded, max_in_flight=2)
    for n in tables:
        np.testing.assert_array_equal(outs_o[n], outs_g[n])
        want = np.asarray(reduce_dense_oracle(
            jnp.asarray(tables[n]), streams[n]))
        np.testing.assert_array_equal(outs_o[n], want)
    st = srv_o.stats.summary()
    assert st["flush_policy"] == "owner-set"
    assert st["batches"] >= 1
    if num_shards > 1:
        # no flush may stack more schedules than the mesh has shards
        assert max(int(k) for k in st["participant_sizes"]) <= num_shards


def test_two_owner_traffic_flushes_two_participants():
    """The acceptance contract of owner-set routing: 2-owner traffic on
    a 4-shard mesh flushes with participant sets of size two — never
    the near-mesh-wide pool the PR-4 scheduler collapsed it into."""
    rows, dim, S = 160, 128, 4
    tables = {"a": _int_table(rows, dim, 65)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=66)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=S, q_block=4, group_size=16,
        batch_size=8, flush_policy="owner-set",
    )
    by_owner = _owner_rows(srv.scheduler, "a")
    if len(by_owner) < 2:
        return  # vacuous at this seed
    owners = sorted(by_owner)
    a, b = owners[0], owners[1]
    stream = [
        [by_owner[a][i % len(by_owner[a])], by_owner[b][i % len(by_owner[b])]]
        for i in range(24)
    ]
    for q in stream:
        srv.submit("a", q)
    out = srv.drain()
    sizes = {int(k) for k in srv.stats.summary()["participant_sizes"]}
    assert sizes == {2}, (
        f"2-owner traffic flushed with participant sizes {sizes}"
    )
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)


# ------------------------------------------------------- thread driver --


def test_thread_driver_submit_is_enqueue_only():
    """Under the thread driver submit() never dispatches inline: the
    driver owns compile/dispatch/retire, results arrive at drain(), and
    submit-side latency samples are recorded for every call."""
    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 67)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=68)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=4, flush_policy="per-shard", threaded=True,
        max_in_flight=1,
    )
    stream = zipf_queries(rows, 23, 5.0, seed=69)
    for q in stream:
        assert srv.submit("a", q) == {}
    out = srv.drain()
    srv.close()
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)
    assert len(srv.stats.submit_wall) == len(stream)
    assert len(srv.stats.flush_wall) == srv.stats.batches
    st = srv.stats.summary()
    assert st["submit_latency_s"]["p50"] <= st["submit_latency_s"]["p95"]
    assert st["submit_latency_s"]["p95"] <= st["submit_latency_s"]["p99"]
    # a second drain with no traffic returns nothing and is harmless
    assert srv.drain() == {}


def test_thread_driver_surfaces_failures_and_retries():
    """A flush failure on the driver thread requeues its batch and
    surfaces at the next submit()/drain(); a later drain retries the
    requeued work and returns every row in submission order.  Pinned on
    ``RetryPolicy.legacy()`` — the default policy heals on the driver
    thread without surfacing (test_faults.py)."""
    import time as _time

    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 70)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=71)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=1, q_block=4, group_size=16,
        batch_size=8, flush_policy="per-shard", threaded=True,
        retry=RetryPolicy.legacy(),
    )
    calls = {"n": 0}
    orig = srv._compile_and_dispatch

    def flaky(entries, participants):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient device error")
        return orig(entries, participants)

    srv._compile_and_dispatch = flaky
    stream = zipf_queries(rows, 9, 5.0, seed=72)
    for q in stream[:8]:
        srv.submit("a", q)  # 8th trips the flush on the driver → fails
    deadline = _time.monotonic() + 10.0
    while not srv._driver_errors and _time.monotonic() < deadline:
        _time.sleep(0.005)
    assert srv._driver_errors, "driver never recorded the failure"
    with pytest.raises(RuntimeError, match="transient device error"):
        srv.drain()
    out = srv.drain()  # retry: the requeued batch flushes cleanly now
    for q in stream[8:]:
        srv.submit("a", q)
    out2 = srv.drain()
    srv.close()
    got = np.concatenate([np.asarray(out["a"]), np.asarray(out2["a"])])
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(got, want)


def test_close_preserves_handoff_backlog():
    """close() must never drop submitted queries: whatever the driver
    had not yet popped from the hand-off queue is pushed back into the
    scheduler, and a later (inline) drain serves every row in
    submission order."""
    rows, dim = 160, 128
    tables = {"a": _int_table(rows, dim, 75)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=76)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=64, flush_policy="per-shard", threaded=True,
    )
    stream = zipf_queries(rows, 9, 5.0, seed=77)
    for q in stream:
        srv.submit("a", q)
    srv.close()  # races the driver: any undispatched backlog must survive
    assert srv._driver is None
    out = srv.drain()  # driver stopped → inline barrier
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)


def test_latency_percentiles_sanity():
    from repro.serve.sharded import _latency_percentiles

    assert _latency_percentiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    pct = _latency_percentiles([1.0, 2.0, 3.0, 4.0])
    assert pct["p50"] <= pct["p95"] <= pct["p99"] <= 4.0
    assert pct["p50"] == 2.5


# ------------------------------------- PlanPatch × async-flush barrier --


def _drifting_async_server(rows=128, dim=128, **kw):
    tables = {"a": _int_table(rows, dim, 31)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=32)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=8, flush_policy="per-shard",
        replan=ReplanConfig(threshold=0.2, half_life=1.0, min_queries=8,
                            slack_tiles=4),
        **kw,
    )
    return srv, tables


@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_patch_staged_mid_pipeline_applies_at_barrier_only(num_shards):
    """A patch staged while flushes are in flight must wait for the
    barrier: placement arrays never swap with work in the pipeline, and
    the drained outputs stay exact across the plan transition."""
    rows, dim = 128, 128
    tables = {"a": _int_table(rows, dim, 31)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=32)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=num_shards, q_block=4, group_size=16,
        # eq1_batch large enough that Eq. 1 replicates groups even at 4
        # shards — otherwise every drift event is a rebase and nothing
        # ever stages
        batch_size=8, batch_size_for_eq1=512,
        flush_policy="per-shard", max_in_flight=4,
        replan=ReplanConfig(threshold=0.15, half_life=1.0, min_queries=8,
                            slack_tiles=8),
    )
    applied_with_in_flight = []
    orig_apply = srv._apply_staged_patch

    def spy_apply():
        if srv._staged is not None:
            applied_with_in_flight.append(len(srv._in_flight))
        orig_apply()

    srv._apply_staged_patch = spy_apply

    stream = zipf_queries(rows, 48, 5.0, seed=33)
    perm = np.random.default_rng(34).permutation(rows)
    stream = stream[:16] + [perm[np.asarray(q, np.int64)] for q in stream[16:]]
    saw_staged_mid_pipeline = False
    for q in stream:
        srv.submit("a", q)
        if srv._staged is not None and srv._in_flight:
            saw_staged_mid_pipeline = True
    out = srv.drain()
    assert saw_staged_mid_pipeline, "drift never staged while in flight"
    assert applied_with_in_flight, "no patch was ever applied"
    assert all(n == 0 for n in applied_with_in_flight), (
        "patch applied with flushes in flight"
    )
    assert srv.stats.replans + srv.stats.rebases >= 1
    assert srv.stats.barrier_flushes >= 1
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)


def test_patch_applies_at_barrier_only_under_thread_driver():
    """The §7.3 barrier rule must survive the thread driver: a patch
    staged by driver-side flushes applies only with the pipeline empty
    (spied on the driver thread), and the drained outputs stay exact
    across the plan transition."""
    rows, dim = 128, 128
    tables = {"a": _int_table(rows, dim, 31)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=32)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=8, batch_size_for_eq1=512,
        flush_policy="per-shard", max_in_flight=4, threaded=True,
        replan=ReplanConfig(threshold=0.15, half_life=1.0, min_queries=8,
                            slack_tiles=8),
    )
    applied_with_in_flight = []
    orig_apply = srv._apply_staged_patch

    def spy_apply():
        if srv._staged is not None:
            applied_with_in_flight.append(len(srv._in_flight))
        orig_apply()

    srv._apply_staged_patch = spy_apply
    stream = zipf_queries(rows, 48, 5.0, seed=33)
    perm = np.random.default_rng(34).permutation(rows)
    stream = stream[:16] + [perm[np.asarray(q, np.int64)] for q in stream[16:]]
    for q in stream:
        srv.submit("a", q)
    out = srv.drain()
    srv.close()
    assert applied_with_in_flight, "no patch was ever applied"
    assert all(n == 0 for n in applied_with_in_flight), (
        "patch applied with flushes in flight"
    )
    assert srv.stats.replans + srv.stats.rebases >= 1
    assert srv.stats.barrier_flushes >= 1
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)


def test_sync_serve_barriers_pending_async_queries():
    """A synchronous serve() call on an async server is a barrier: the
    pending (not yet flushed) queries must flush under the plan they
    were routed against BEFORE a staged patch applies — stale routing
    would compile them onto shards that no longer own their groups."""
    rows, dim = 128, 128
    tables = {"a": _int_table(rows, dim, 31)}
    histories = {"a": zipf_queries(rows, 48, 5.0, seed=32)}
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=2, q_block=4, group_size=16,
        batch_size=8, batch_size_for_eq1=512,
        flush_policy="per-shard", max_in_flight=4,
        replan=ReplanConfig(threshold=0.15, half_life=1.0, min_queries=8,
                            slack_tiles=8),
    )
    stream = zipf_queries(rows, 44, 5.0, seed=33)
    perm = np.random.default_rng(34).permutation(rows)
    stream = stream[:16] + [perm[np.asarray(q, np.int64)] for q in stream[16:]]
    probe = zipf_queries(rows, 5, 5.0, seed=36)
    served = []
    for i, q in enumerate(stream):
        srv.submit("a", q)
        if i == len(stream) - 3:
            # mid-replay sync serve: pending queries + (likely) a
            # staged patch are both outstanding right now
            served.append(("probe", np.asarray(srv.serve({"a": probe})["a"])))
    out = srv.drain()
    np.testing.assert_array_equal(
        served[0][1],
        np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), probe)),
    )
    want = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)
    assert srv.stats.replans >= 1  # the patch really applied en route


def test_patched_async_server_matches_fresh_rebuild():
    """After the async replay's patches, the live plan must serve a
    probe bit-identically to a from-scratch plan_shards rebuild on the
    plan's (drifted) load snapshot — the §6 invariant holding through
    the §7 engine."""
    rows, dim, S = 128, 128, 2
    hist = zipf_queries(rows, 48, 5.0, seed=32)
    layout, plan, gfreq = _pipeline(rows, hist, dim=dim)
    tables = {"a": _int_table(rows, dim, 31)}
    srv = ShardedEmbeddingServer(
        tables, {"a": hist}, num_shards=S, q_block=4, group_size=16,
        batch_size=8, flush_policy="per-shard",
        replan=ReplanConfig(threshold=0.2, half_life=1.0, min_queries=8,
                            slack_tiles=4),
    )
    stream = zipf_queries(rows, 48, 5.0, seed=33)
    perm = np.random.default_rng(34).permutation(rows)
    stream = stream[:16] + [perm[np.asarray(q, np.int64)] for q in stream[16:]]
    for q in stream:
        srv.submit("a", q)
    srv.drain()
    if srv.stats.replans == 0:
        return  # no class change at this seed; vacuous
    # the patched plan's group_load IS the drifted snapshot Eq. 1 saw
    fresh = plan_shards(
        [layout], [plan], S,
        group_freqs=[srv.plan.group_load], eq1_batch=srv._eq1_batch,
    )
    np.testing.assert_array_equal(
        srv.plan.replicated_group, fresh.replicated_group
    )
    probe = zipf_queries(rows, 11, 5.0, seed=35)
    out_srv = srv.serve({"a": probe})["a"]
    fused = build_fused_image([layout], [tables["a"]])
    images_f = jnp.asarray(fresh.build_shard_images(fused))
    cq = compile_queries(layout, probe, replica_block=4)
    sbq = shard_block_queries(cq, fresh, 4)
    out_f = np.asarray(crossbar_reduce_sharded(
        images_f, sbq.tile_ids, sbq.bitmaps
    ))[: sbq.batch]
    np.testing.assert_array_equal(np.asarray(out_srv), out_f)


def test_shard_map_async_serving_subprocess():
    """The REAL shard_map path must run the async engine — subset
    flushes scattered into the full device stack — bit-identically to
    the global policy.  Device forcing must precede jax init →
    subprocess with 2 host devices."""
    import os
    import subprocess
    import sys

    script = r"""
import numpy as np
import jax, jax.numpy as jnp
assert len(jax.devices()) >= 2, jax.devices()
import sys
sys.path.insert(0, {src!r})
from repro.data import zipf_queries
from repro.serve import ShardedEmbeddingServer
from repro.serve.drift import ReplanConfig
from repro.core.reduction import reduce_dense_oracle

rows, dim, S = 96, 128, 2
tables = {{"a": np.random.default_rng(3).integers(
    -8, 9, size=(rows, dim)).astype(np.float32)}}
histories = {{"a": zipf_queries(rows, 32, 5.0, seed=1)}}
stream = zipf_queries(rows, 30, 5.0, seed=2)
perm = np.random.default_rng(4).permutation(rows)
stream = stream[:10] + [perm[np.asarray(q, np.int64)] for q in stream[10:]]
mesh = jax.make_mesh((1, S), ("data", "model"))

def run(policy, mesh, **kw):
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=S, mesh=mesh, q_block=4,
        group_size=16, batch_size=8, flush_policy=policy,
        replan=ReplanConfig(threshold=0.2, half_life=1.0, min_queries=8,
                            slack_tiles=4),
        **kw)
    outs = []
    for q in stream:
        for _, o in srv.submit("a", q).items():
            outs.append(np.asarray(o))
    for _, o in srv.flush().items():
        outs.append(np.asarray(o))
    return srv, np.concatenate(outs)

srv_sm, out_sm = run("per-shard", mesh)
srv_emu, out_emu = run("per-shard", None)
srv_g, out_g = run("global", mesh)
np.testing.assert_array_equal(out_sm, out_emu)
np.testing.assert_array_equal(out_sm, out_g)
oracle = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
np.testing.assert_array_equal(out_sm, oracle)
assert srv_sm.stats.batches >= 2
print("SCHEDULER_SHARD_MAP_PARITY_OK")
""".format(src=os.path.join(os.path.dirname(__file__), "..", "src"))

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=2 "
        + env.get("XLA_FLAGS", "")
    )
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=480,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SCHEDULER_SHARD_MAP_PARITY_OK" in proc.stdout


def test_owner_set_thread_driver_shard_map_subprocess():
    """Owner-set homes + the thread driver on the REAL shard_map path
    (4 forced host devices): 2-owner flushes dispatch the grouped-psum
    subset combine and everything stays bit-identical to emulation, the
    global policy, and the oracle.  Device forcing must precede jax
    init → subprocess."""
    import os
    import subprocess
    import sys

    script = r"""
import numpy as np
import jax, jax.numpy as jnp
assert len(jax.devices()) >= 4, jax.devices()
import sys
sys.path.insert(0, {src!r})
from repro.data import zipf_queries
from repro.serve import ShardedEmbeddingServer
from repro.core.reduction import reduce_dense_oracle

rows, dim, S = 96, 128, 4
tables = {{"a": np.random.default_rng(3).integers(
    -8, 9, size=(rows, dim)).astype(np.float32)}}
histories = {{"a": zipf_queries(rows, 32, 5.0, seed=1)}}
mesh = jax.make_mesh((1, S), ("data", "model"))

# owner map for crafting 2-owner queries (read off a probe server)
probe = ShardedEmbeddingServer(
    tables, histories, num_shards=S, q_block=4, group_size=16,
    batch_size=8, flush_policy="owner-set")
owner = probe.scheduler._owner_of_row["a"]
by_owner = {{}}
for r, o in enumerate(owner):
    if o >= 0:
        by_owner.setdefault(int(o), []).append(r)
owners = sorted(by_owner)
assert len(owners) >= 2, owners
a, b = owners[0], owners[1]
stream = list(zipf_queries(rows, 18, 5.0, seed=2))
stream += [
    [by_owner[a][i % len(by_owner[a])], by_owner[b][i % len(by_owner[b])]]
    for i in range(10)
]

def run(policy, mesh, **kw):
    srv = ShardedEmbeddingServer(
        tables, histories, num_shards=S, mesh=mesh, q_block=4,
        group_size=16, batch_size=8, flush_policy=policy, **kw)
    outs = []
    for q in stream:
        for _, o in srv.submit("a", q).items():
            outs.append(np.asarray(o))
    for _, o in srv.flush().items():
        outs.append(np.asarray(o))
    srv.close()
    return srv, np.concatenate(outs)

srv_sm, out_sm = run("owner-set", mesh, threaded=True)
srv_emu, out_emu = run("owner-set", None, threaded=True)
srv_g, out_g = run("global", mesh)
np.testing.assert_array_equal(out_sm, out_emu)
np.testing.assert_array_equal(out_sm, out_g)
oracle = np.asarray(reduce_dense_oracle(jnp.asarray(tables["a"]), stream))
np.testing.assert_array_equal(out_sm, oracle)
sizes = {{int(k) for k in srv_sm.stats.summary()["participant_sizes"]}}
assert 2 in sizes, sizes   # the grouped-psum subset combine really ran
assert len(srv_sm.stats.submit_wall) == len(stream)
print("OWNER_SET_THREAD_DRIVER_SHARD_MAP_OK")
""".format(src=os.path.join(os.path.dirname(__file__), "..", "src"))

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + env.get("XLA_FLAGS", "")
    )
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=480,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OWNER_SET_THREAD_DRIVER_SHARD_MAP_OK" in proc.stdout
