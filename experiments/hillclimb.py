"""Perf hillclimb driver: run optimization variants of the three chosen
cells and print before/after roofline terms.

Usage: PYTHONPATH=src python experiments/hillclimb.py
Results land next to the baselines in experiments/dryrun/ with variant
suffixes; the comparison table prints at the end (pasted into
EXPERIMENTS.md §Perf together with the hypothesis log).
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import json

from repro.launch.dryrun import RESULTS_DIR, run_cell, run_dlrm_cell

import dataclasses as _dc
from repro.configs.base import MoEConfig

RUNS = [
    # (kind, arch, shape, variant)
    # --- dlrm (paper-representative, collective-dominant): Eq.1 sharding
    ("dlrm", None, None, {"name": "hotrep10", "hot_fraction": 0.10}),
    ("dlrm", None, None, {"name": "smbag", "shardmap_bag": True}),
    ("dlrm", None, None, {"name": "smbag_hotrep", "shardmap_bag": True, "hot_fraction": 0.10}),
    # --- minicpm decode (collective-dominant): cache-axis + datapath iterations
    ("lm", "minicpm-2b", "decode_32k", {"name": "cacheseq", "cache_seq_shard": True}),
    ("lm", "minicpm-2b", "decode_32k",
     {"name": "cacheseq_ro", "cache_seq_shard": True, "readonly_cache": True}),
    ("lm", "minicpm-2b", "decode_32k",
     {"name": "cacheseq_int8", "cache_seq_shard": True, "kv_quant": True}),
    # --- granite (most collective-bound train): dispatch grouping w/ seq-cache
    ("lm", "granite-moe-3b-a800m", "train_4k",
     {"name": "moegroup256", "cfg_overrides": {"moe_groups": 256}}),
]


def summarize(rec):
    r = rec["roofline"]
    return (f"{rec['cell']:62s} dom={r['dominant']:10s} "
            f"comp={r['compute_s']*1e3:9.2f}ms mem={r['memory_s']*1e3:8.2f}ms "
            f"coll={r['collective_s']*1e3:8.2f}ms "
            f"mem/dev={rec['memory_analysis']['per_device_total_gib']:5.1f}GiB")


def main():
    # re-run the dlrm baseline with the current (inline-loss) code so the
    # hotrep comparison is same-code
    base = run_dlrm_cell(multi_pod=False, force=True)
    print(summarize(base))
    for kind, arch, shape, variant in RUNS:
        try:
            if kind == "dlrm":
                rec = run_dlrm_cell(multi_pod=False, variant=variant, force=True)
            else:
                rec = run_cell(arch, shape, multi_pod=False, variant=variant, force=True)
            print(summarize(rec))
        except Exception as e:
            print(f"FAIL {arch}/{shape}/{variant.get('name')}: {e!r}")


if __name__ == "__main__":
    main()
