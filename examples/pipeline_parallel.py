"""Pipeline-parallelism demo: 4 stages × 8 microbatches on placeholder
devices, validated against sequential execution.

Run: PYTHONPATH=src python examples/pipeline_parallel.py
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist.pipeline_parallel import bubble_fraction, pipelined_apply

S, M, MB, D, LAYERS_PER_STAGE = 4, 8, 16, 64, 3

mesh = jax.make_mesh((S,), ("stage",))
rng = jax.random.PRNGKey(0)

# stacked per-stage params: (S, layers_per_stage, D, D)
w = jax.random.normal(rng, (S, LAYERS_PER_STAGE, D, D)) * (1.0 / np.sqrt(D))
x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))


def stage_body(w_stage, h):
    def layer(c, wl):
        return jnp.tanh(c @ wl), None
    out, _ = jax.lax.scan(layer, h, w_stage)
    return out


out_pp = jax.jit(
    lambda ww, xx: pipelined_apply(ww, xx, stage_body, mesh)
)(w, x)

# sequential reference: all S*L layers in order
w_flat = w.reshape(S * LAYERS_PER_STAGE, D, D)
ref = jax.vmap(lambda xb: stage_body(w_flat, xb))(x)

err = float(jnp.abs(out_pp - ref).max())
print(f"stages={S} microbatches={M} ticks={M + S - 1} "
      f"bubble={bubble_fraction(M, S):.1%}")
print(f"pipeline vs sequential max |Δ| = {err:.2e}")
assert err < 1e-5
print("pipelined execution matches sequential ✓")
