"""End-to-end driver: train a DLRM with ReCross embedding reduction.

Trains a smoke-scale DLRM on synthetic CTR data for a few hundred steps,
with the embedding reduction running through the ReCross layout (Pallas
kernel path), demonstrating that the paper's datapath is differentiable
and trainable — gradients flow through crossbar_reduce's custom VJP back
into the (permuted, replicated) table image; the logical table is
refreshed from the image at checkpoints.

Run: PYTHONPATH=src python examples/train_dlrm.py [--steps 300]
"""

import argparse
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.dlrm_recross import smoke as dlrm_smoke
from repro.core import baselines, build_cooccurrence
from repro.core.reduction import compile_queries
from repro.data import zipf_queries
from repro.models.dlrm import build_images, dlrm_forward, init_dlrm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-2)
    args = ap.parse_args()

    cfg = dlrm_smoke()
    rng = jax.random.PRNGKey(0)
    params = init_dlrm(rng, cfg)

    # offline phase per table
    layouts = {}
    for t in range(cfg.num_tables):
        hist = zipf_queries(cfg.rows_per_table, 256, 8.0, seed=100 + t)
        graph = build_cooccurrence(hist, cfg.rows_per_table)
        layouts[f"t{t}"], _ = baselines.recross_pipeline(
            graph, hist, group_size=cfg.group_size, dim=cfg.embed_dim
        )
    images = build_images(params, cfg, layouts)
    # train the images directly (they ARE the device-resident table)
    trainable = {"images": images, "bottom": params["bottom"], "top": params["top"]}

    kcfg = dataclasses.replace(cfg, embedding_path="kernel")

    def loss_fn(tr, dense, sparse, labels):
        p = {"tables": params["tables"], "bottom": tr["bottom"], "top": tr["top"]}
        logits = dlrm_forward(p, kcfg, dense, sparse, images=tr["images"])
        return jnp.mean(
            jnp.maximum(logits, 0) - logits * labels
            + jnp.log1p(jnp.exp(-jnp.abs(logits)))
        ), logits

    @jax.jit
    def step_fn(tr, dense, sparse, labels):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            tr, dense, sparse, labels
        )
        tr = jax.tree.map(lambda p, g: p - args.lr * g.astype(p.dtype), tr, grads)
        acc = jnp.mean((logits > 0) == (labels > 0.5))
        return tr, loss, acc

    rng_np = np.random.default_rng(0)
    # synthetic CTR rule: label depends on overlap of two tables' hot items
    losses = []
    for step in range(args.steps):
        qs = {f"t{t}": zipf_queries(cfg.rows_per_table, args.batch, 8.0,
                                    seed=step * 7 + t) for t in range(cfg.num_tables)}
        dense = rng_np.normal(size=(args.batch, cfg.dense_features)).astype(np.float32)
        hot = sum((np.array([q.min() for q in qs[f"t{t}"]]) < 64).astype(np.float32)
                  for t in range(cfg.num_tables))
        labels = ((hot + dense[:, 0] > 1.0)).astype(np.float32)
        sparse = {}
        for t in range(cfg.num_tables):
            cq = compile_queries(layouts[f"t{t}"], qs[f"t{t}"], max_tiles=32)
            sparse[f"t{t}"] = (cq.tile_ids, cq.bitmaps)
        trainable, loss, acc = step_fn(trainable, jnp.asarray(dense), sparse,
                                       jnp.asarray(labels))
        losses.append(float(loss))
        if step % 50 == 0 or step == args.steps - 1:
            print(f"step {step:4d} bce {float(loss):.4f} acc {float(acc):.3f}")
    assert np.mean(losses[-20:]) < np.mean(losses[:20]), "training did not improve"
    print("final-20 loss %.4f < first-20 loss %.4f  ✓ (trained through the "
          "ReCross kernel datapath)" % (np.mean(losses[-20:]), np.mean(losses[:20])))


if __name__ == "__main__":
    main()
