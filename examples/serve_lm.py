"""Serve a smoke-scale LM with continuous batching (batched requests).

Demonstrates the serving stack: KV caches, slot-based continuous
batching, per-request TTFT/latency metrics.

Run: PYTHONPATH=src python examples/serve_lm.py --arch chatglm3-6b
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0]] + (sys.argv[1:] or ["--arch", "chatglm3-6b",
                                                  "--requests", "6", "--slots", "3"])
    main()
