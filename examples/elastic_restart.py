"""Elastic re-mesh demo: checkpoint on a 256-chip mesh, lose 128 chips,
resume on the surviving 128 with identical numerics.

Exercises the production fault-tolerance path end to end on the
512-placeholder-device host:

  1. train a smoke LM 6 steps on mesh A = (data=16, model=16), sharded
     FSDP x TP, saving a checkpoint;
  2. "lose half the fleet": plan_remesh(128 chips, tp=16) -> (8, 16);
  3. restore the checkpoint onto mesh B with re-sharding-on-load
     (checkpoint.restore re-places every leaf with the new shardings);
  4. continue training; verify the loss trajectory matches a run that
     never crashed (deterministic pipeline + exact state carry-over).

Run: PYTHONPATH=src python examples/elastic_restart.py
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import tempfile

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data.pipeline import TokenBatcher
from repro.dist.sharding import (
    LOGICAL_RULES_SINGLE_POD,
    activation_sharding_ctx,
    param_specs_for,
    sanitize_specs_tree,
)
from repro.models.transformer import init_lm
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import plan_remesh
from repro.train.loop import TrainState, init_train_state, make_train_step
from repro.train.optimizer import AdamW

STEPS_BEFORE, STEPS_AFTER = 6, 6


def shardings_for(state, mesh):
    p_specs = sanitize_specs_tree(
        param_specs_for(state.params, LOGICAL_RULES_SINGLE_POD),
        jax.eval_shape(lambda: state.params), mesh,
    )
    to_ns = lambda specs: jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    from repro.launch.dryrun import opt_state_specs
    o_specs = opt_state_specs(jax.eval_shape(lambda: state.opt_state), p_specs, mesh)
    return TrainState(
        params=to_ns(p_specs), opt_state=to_ns(o_specs),
        step=NamedSharding(mesh, P()),
    )


def run(mesh, state, data, start, steps, opt, cfg):
    step_fn = jax.jit(make_train_step(cfg, opt))
    losses = []
    with activation_sharding_ctx(mesh, LOGICAL_RULES_SINGLE_POD):
        for s in range(start, start + steps):
            tokens, labels = data.batch(s)
            state, m = step_fn(state, {"tokens": tokens, "labels": labels})
            losses.append(float(m["loss"]))
    return state, losses


def main():
    cfg = get_config("minicpm-2b", smoke=True)
    opt = AdamW(schedule=lambda s: 1e-3)
    data = TokenBatcher(cfg.vocab_size, batch_size=16, seq_len=32, seed=0)

    mesh_a = jax.make_mesh((16, 16), ("data", "model"))
    print(f"mesh A: {mesh_a.devices.shape} = {mesh_a.devices.size} chips")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, opt)
    sh_a = shardings_for(state, mesh_a)
    state = jax.tree.map(jax.device_put, state, sh_a)

    state, losses_a = run(mesh_a, state, data, 0, STEPS_BEFORE, opt, cfg)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, STEPS_BEFORE, state)
        print(f"checkpointed at step {STEPS_BEFORE}; losses so far: "
              f"{[round(l, 4) for l in losses_a]}")

        # --- failure: 8 of 16 hosts die -> 128 chips survive ---------------
        new_shape = plan_remesh(n_hosts=8, chips_per_host=16, model_parallelism=16)
        print(f"re-mesh plan for survivors: {new_shape}")
        mesh_b = jax.make_mesh(new_shape, ("data", "model"))

        like = jax.eval_shape(lambda: state)
        sh_b = shardings_for(state, mesh_b)
        restored = ckpt.restore(d, STEPS_BEFORE, like, shardings=sh_b)
        print("restored onto mesh B with re-sharding-on-load")

    state_b, losses_b = run(mesh_b, restored, data, STEPS_BEFORE, STEPS_AFTER, opt, cfg)

    # --- reference: uninterrupted run on mesh A ----------------------------
    params_ref = init_lm(jax.random.PRNGKey(0), cfg)
    state_ref = jax.tree.map(jax.device_put, init_train_state(params_ref, opt), sh_a)
    state_ref, ref_a = run(mesh_a, state_ref, data, 0, STEPS_BEFORE, opt, cfg)
    state_ref, ref_b = run(mesh_a, state_ref, data, STEPS_BEFORE, STEPS_AFTER, opt, cfg)

    diffs = [abs(a - b) for a, b in zip(losses_b, ref_b)]
    print(f"post-restart losses (128 chips): {[round(l, 4) for l in losses_b]}")
    print(f"uninterrupted losses (256 chips): {[round(l, 4) for l in ref_b]}")
    print(f"max |Δloss| = {max(diffs):.2e}")
    assert max(diffs) < 5e-3, "elastic restart diverged from uninterrupted run"
    print("elastic re-mesh resume matches the uninterrupted trajectory ✓")


if __name__ == "__main__":
    main()
