"""Ablation: which ReCross component buys what (paper §IV-B decomposition).

Runs the simulator on one workload with components toggled:
  naive → +grouping → +replication → +dynamic switch (full ReCross)
and prints the waterfall of completion time and energy.

Run: PYTHONPATH=src python examples/recross_ablation.py
"""

from repro.core import baselines, build_cooccurrence
from repro.data import make_workload

_, rows, qs = make_workload("automotive", num_queries=768, scale=0.02)
hist, online = qs[:256], qs[256:]
graph = build_cooccurrence(hist, rows)

_, naive = baselines.naive_pipeline(rows, online)
_, grouped = baselines.recross_pipeline(
    graph, online, batch_size=256, replication_scheme="none", dynamic_switching=False
)
_, replicated = baselines.recross_pipeline(
    graph, online, batch_size=256, replication_scheme="log", dynamic_switching=False
)
_, full = baselines.recross_pipeline(
    graph, online, batch_size=256, replication_scheme="log", dynamic_switching=True
)

print(f"{'variant':<28}{'time(us)':>10}{'energy(nJ)':>12}{'speedup':>9}{'e-eff':>7}")
for name, rep in [
    ("naive", naive),
    ("+ grouping (Alg.1)", grouped),
    ("+ replication (Eq.1)", replicated),
    ("+ dynamic switch (full)", full),
]:
    print(f"{name:<28}{rep.completion_time_ns/1e3:>10.2f}{rep.energy_pj/1e3:>12.2f}"
          f"{naive.completion_time_ns/rep.completion_time_ns:>8.2f}x"
          f"{naive.energy_pj/rep.energy_pj:>6.2f}x")
print(f"\nread-path fraction with dynamic switch: {full.read_fraction*100:.1f}%")
