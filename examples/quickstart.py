"""Quickstart: the ReCross pipeline end to end in ~60 lines.

1. Synthesize an Amazon-Review-like lookup trace (power-law + clusters).
2. Offline phase: co-occurrence graph → Algorithm-1 grouping → Eq.-1
   log-scaled replication → crossbar layout.
3. Online phase: run embedding reduction three ways (dense oracle,
   tiled-MAC reference, Pallas kernel) and check they agree.
4. Simulate the ReRAM cost of ReCross vs naive/nMARS baselines.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    baselines,
    build_cooccurrence,
    compile_queries,
    mode_statistics,
    simulate_cpu_baseline,
)
from repro.core.mapping import query_tile_bitmaps
from repro.core.reduction import reduce_dense_oracle
from repro.data import zipf_queries
from repro.kernels import crossbar_reduce

NUM_ROWS, DIM, GROUP = 4096, 128, 64

# 1. workload -------------------------------------------------------------
history = zipf_queries(NUM_ROWS, 512, mean_bag=20.0, seed=0)
online = zipf_queries(NUM_ROWS, 256, mean_bag=20.0, seed=1)

# 2. offline phase --------------------------------------------------------
graph = build_cooccurrence(history, NUM_ROWS)
layout, recross_report = baselines.recross_pipeline(
    graph, online, group_size=GROUP, dim=DIM, batch_size=256
)
print(f"offline: {graph.edge_count()} co-occurrence edges -> "
      f"{layout.num_groups} groups, {layout.num_tiles} tiles "
      f"(replication ratio {layout.num_tiles / layout.num_groups:.2f})")

# 3. online phase: three numerically identical datapaths ------------------
table = np.random.default_rng(0).normal(size=(NUM_ROWS, DIM)).astype(np.float32)
image = jnp.asarray(
    layout.build_image(table).reshape(layout.num_tiles, layout.tile_rows, DIM)
)
cq = compile_queries(layout, online[:32])
out_kernel = crossbar_reduce(image, cq.tile_ids, cq.bitmaps)
out_oracle = reduce_dense_oracle(jnp.asarray(table), online[:32])
assert np.allclose(out_kernel, out_oracle, atol=1e-3), "kernel != oracle"
print("online: Pallas crossbar_reduce matches the dense oracle  ✓")

_, counts = query_tile_bitmaps(layout, online[:256])
stats = mode_statistics(counts)
print(f"dynamic switch: {stats['read_fraction']*100:.1f}% of activations take "
      f"the READ path (single embedding)")

# 4. cost simulation ------------------------------------------------------
_, naive = baselines.naive_pipeline(NUM_ROWS, online)
_, nmars = baselines.nmars_pipeline(NUM_ROWS, online)
cpu = simulate_cpu_baseline(online)
print(f"simulated speedup   : {recross_report.speedup_over(naive):.2f}x vs naive, "
      f"{recross_report.speedup_over(nmars):.2f}x vs nMARS")
print(f"simulated energy eff: {recross_report.energy_efficiency_over(naive):.2f}x vs naive, "
      f"{cpu.energy_pj / recross_report.energy_pj:.0f}x vs CPU")
